//! Bench trend history and the regression gate — the comparison logic
//! behind `bin/bench_trend` (`make bench-trend`).
//!
//! Each bench run leaves `BENCH_<name>.json` reports (obs::bench_report).
//! This module turns one run's reports into a trend *point* (flattened
//! `bench/metric` values keyed by git rev + timestamp), appends it to a
//! schema-stable history (`benches/trend/data.json`), and diffs the run
//! against a committed baseline (`benches/baseline/`): a headline metric
//! moving in its bad direction by more than the threshold is a
//! regression, and the gate exits non-zero. Pure functions over
//! [`Json`] — all file I/O lives in the binary, so every branch here is
//! unit-testable without touching the filesystem.
//!
//! History schema (additive-only, like the stats snapshot):
//!
//! ```json
//! {"schema": 1, "points": [
//!   {"rev": "5c8b93f", "timestamp": 1754550000,
//!    "metrics": {"serve_batch/decode_tok_s_pipelined": 512.0, ...}},
//!   ...]}
//! ```

use std::collections::BTreeMap;

use crate::util::json::{num, obj, s, Json};

/// A gated metric: its report, its key, and which direction is bad.
#[derive(Debug, Clone, Copy)]
pub struct Headline {
    pub bench: &'static str,
    pub metric: &'static str,
    pub higher_is_better: bool,
}

/// The metrics the gate fails on. Everything else still lands in the
/// trend history for inspection — gating on every noisy micro-metric
/// would make the gate cry wolf; these are the serving headlines the
/// paper's claims ride on.
pub const HEADLINES: &[Headline] = &[
    Headline { bench: "serve_batch", metric: "decode_tok_s_pipelined", higher_is_better: true },
    Headline { bench: "serve_batch", metric: "decode_tok_s_single_thread", higher_is_better: true },
    Headline { bench: "serve_batch", metric: "host_device_overlap_frac", higher_is_better: true },
    Headline { bench: "serve_batch", metric: "ttft_p50_ms_pipelined", higher_is_better: false },
    Headline { bench: "prefix_cache", metric: "warm_prefill_s", higher_is_better: false },
    Headline { bench: "perf_router", metric: "prefix_hit_rate_affinity", higher_is_better: true },
];

/// Default relative-change gate (`HAE_TREND_THRESHOLD` overrides): a
/// headline may move up to 10% in its bad direction before failing.
pub const DEFAULT_THRESHOLD: f64 = 0.10;

/// Points retained in the trend history before the oldest fall off.
pub const HISTORY_CAP: usize = 500;

/// Pull one metric value out of a `BENCH_*.json` report object.
pub fn metric_value(report: &Json, metric: &str) -> Option<f64> {
    report.path(&["metrics", metric, "value"]).and_then(|v| v.as_f64())
}

/// One headline that moved beyond the threshold in its bad direction.
#[derive(Debug, Clone)]
pub struct Regression {
    pub bench: String,
    pub metric: String,
    pub baseline: f64,
    pub current: f64,
    /// signed relative change, `(current - baseline) / baseline`
    pub change_frac: f64,
}

impl Regression {
    pub fn describe(&self) -> String {
        format!(
            "{}/{}: baseline {:.4} -> current {:.4} ({:+.1}%)",
            self.bench,
            self.metric,
            self.baseline,
            self.current,
            100.0 * self.change_frac
        )
    }
}

/// Outcome of diffing one run against the baseline.
#[derive(Debug, Default)]
pub struct Comparison {
    /// headlines present on both sides and within threshold
    pub ok: Vec<String>,
    /// headlines missing a side (report or metric absent) — reported,
    /// never failed on: a baseline refresh must not brick the gate
    pub skipped: Vec<String>,
    pub regressions: Vec<Regression>,
}

/// Diff the current run's reports (bench name → report object) against
/// the baseline's. Only [`HEADLINES`] are gated; a metric regresses when
/// it moves more than `threshold` (relative) in its bad direction.
pub fn compare(
    current: &BTreeMap<String, Json>,
    baseline: &BTreeMap<String, Json>,
    threshold: f64,
) -> Comparison {
    let mut out = Comparison::default();
    for h in HEADLINES {
        let key = format!("{}/{}", h.bench, h.metric);
        let cur = current.get(h.bench).and_then(|r| metric_value(r, h.metric));
        let base = baseline.get(h.bench).and_then(|r| metric_value(r, h.metric));
        let (cur, base) = match (cur, base) {
            (Some(c), Some(b)) if b > 0.0 => (c, b),
            // absent on either side, or a degenerate zero baseline the
            // relative change is undefined against
            _ => {
                out.skipped.push(key);
                continue;
            }
        };
        let change_frac = (cur - base) / base;
        let regressed = if h.higher_is_better {
            change_frac < -threshold
        } else {
            change_frac > threshold
        };
        if regressed {
            out.regressions.push(Regression {
                bench: h.bench.to_string(),
                metric: h.metric.to_string(),
                baseline: base,
                current: cur,
                change_frac,
            });
        } else {
            out.ok.push(key);
        }
    }
    out
}

/// The process exit status the gate maps a comparison to.
pub fn exit_code(cmp: &Comparison) -> i32 {
    if cmp.regressions.is_empty() {
        0
    } else {
        1
    }
}

/// Flatten one run's reports into a trend point: every metric of every
/// report as `"bench/metric": value`, stamped with the run's rev and
/// timestamp (taken from the first report that carries them — one run
/// writes all its reports at the same rev).
pub fn trend_point(reports: &BTreeMap<String, Json>) -> Json {
    let rev = reports
        .values()
        .find_map(|r| r.get("rev").and_then(|v| v.as_str()).map(String::from))
        .unwrap_or_else(|| "unknown".to_string());
    let timestamp = reports
        .values()
        .find_map(|r| r.get("timestamp").and_then(|v| v.as_f64()))
        .unwrap_or(0.0);
    let mut metrics: Vec<(String, Json)> = Vec::new();
    for (bench, report) in reports {
        if let Some(m) = report.get("metrics").and_then(|v| v.as_obj()) {
            for (name, entry) in m {
                if let Some(v) = entry.get("value").and_then(|x| x.as_f64()) {
                    metrics.push((format!("{}/{}", bench, name), num(v)));
                }
            }
        }
    }
    obj(vec![
        ("rev", s(&rev)),
        ("timestamp", num(timestamp)),
        ("metrics", Json::Obj(metrics.into_iter().collect())),
    ])
}

/// Append a point to the history (creating it when `history` is None or
/// malformed), dropping the oldest points past [`HISTORY_CAP`]. The
/// schema marker stays 1 — additions to points are additive-only.
pub fn append_point(history: Option<Json>, point: Json) -> Json {
    let mut points: Vec<Json> = history
        .as_ref()
        .and_then(|h| h.get("points"))
        .and_then(|p| p.as_arr())
        .map(|p| p.to_vec())
        .unwrap_or_default();
    points.push(point);
    if points.len() > HISTORY_CAP {
        let drop = points.len() - HISTORY_CAP;
        points.drain(..drop);
    }
    obj(vec![("schema", num(1.0)), ("points", Json::Arr(points))])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal schema-shaped report: `{"rev","timestamp","metrics":{..}}`.
    fn report(rev: &str, metrics: &[(&str, f64)]) -> Json {
        let m: Vec<(String, Json)> = metrics
            .iter()
            .map(|(k, v)| {
                (k.to_string(), obj(vec![("value", num(*v)), ("unit", s("x"))]))
            })
            .collect();
        obj(vec![
            ("bench", s("test")),
            ("rev", s(rev)),
            ("timestamp", num(1_754_550_000.0)),
            ("engine_threads", num(2.0)),
            ("metrics", Json::Obj(m.into_iter().collect())),
        ])
    }

    fn run(serve_metrics: &[(&str, f64)], warm_prefill_s: f64) -> BTreeMap<String, Json> {
        let mut out = BTreeMap::new();
        out.insert("serve_batch".to_string(), report("abc1234", serve_metrics));
        out.insert(
            "prefix_cache".to_string(),
            report("abc1234", &[("warm_prefill_s", warm_prefill_s)]),
        );
        out
    }

    const BASE_SERVE: &[(&str, f64)] = &[
        ("decode_tok_s_pipelined", 500.0),
        ("decode_tok_s_single_thread", 400.0),
        ("host_device_overlap_frac", 0.5),
        ("ttft_p50_ms_pipelined", 30.0),
    ];

    #[test]
    fn identical_runs_pass_the_gate() {
        let base = run(BASE_SERVE, 0.02);
        let cmp = compare(&base, &base, DEFAULT_THRESHOLD);
        assert!(cmp.regressions.is_empty(), "{:?}", cmp.regressions);
        assert_eq!(cmp.ok.len(), HEADLINES.len());
        assert!(cmp.skipped.is_empty());
        assert_eq!(exit_code(&cmp), 0);
    }

    #[test]
    fn synthetic_decode_regression_fails_the_gate() {
        let base = run(BASE_SERVE, 0.02);
        // 15% decode-throughput drop against a 10% threshold
        let cur = run(
            &[
                ("decode_tok_s_pipelined", 425.0),
                ("decode_tok_s_single_thread", 400.0),
                ("host_device_overlap_frac", 0.5),
                ("ttft_p50_ms_pipelined", 30.0),
            ],
            0.02,
        );
        let cmp = compare(&cur, &base, DEFAULT_THRESHOLD);
        assert_eq!(cmp.regressions.len(), 1, "{:?}", cmp.regressions);
        let r = &cmp.regressions[0];
        assert_eq!(r.metric, "decode_tok_s_pipelined");
        assert!((r.change_frac + 0.15).abs() < 1e-9, "{}", r.change_frac);
        assert_ne!(exit_code(&cmp), 0, "regressed run must exit non-zero");
        assert!(r.describe().contains("decode_tok_s_pipelined"));
    }

    #[test]
    fn lower_is_better_metrics_gate_on_increase() {
        let base = run(BASE_SERVE, 0.02);
        // warm prefill got 50% slower; TTFT improved (must not trip)
        let cur = run(
            &[
                ("decode_tok_s_pipelined", 500.0),
                ("decode_tok_s_single_thread", 400.0),
                ("host_device_overlap_frac", 0.5),
                ("ttft_p50_ms_pipelined", 20.0),
            ],
            0.03,
        );
        let cmp = compare(&cur, &base, DEFAULT_THRESHOLD);
        assert_eq!(cmp.regressions.len(), 1, "{:?}", cmp.regressions);
        assert_eq!(cmp.regressions[0].metric, "warm_prefill_s");
    }

    #[test]
    fn drift_within_threshold_passes() {
        let base = run(BASE_SERVE, 0.02);
        // every headline 8% worse — inside the 10% gate
        let cur = run(
            &[
                ("decode_tok_s_pipelined", 460.0),
                ("decode_tok_s_single_thread", 368.0),
                ("host_device_overlap_frac", 0.46),
                ("ttft_p50_ms_pipelined", 32.4),
            ],
            0.0216,
        );
        let cmp = compare(&cur, &base, DEFAULT_THRESHOLD);
        assert!(cmp.regressions.is_empty(), "{:?}", cmp.regressions);
        // but a tighter threshold catches it
        let tight = compare(&cur, &base, 0.05);
        assert!(!tight.regressions.is_empty());
    }

    #[test]
    fn missing_sides_skip_instead_of_failing() {
        let base = run(BASE_SERVE, 0.02);
        let mut cur = run(BASE_SERVE, 0.02);
        cur.remove("prefix_cache");
        let cmp = compare(&cur, &base, DEFAULT_THRESHOLD);
        assert!(cmp.regressions.is_empty());
        assert_eq!(cmp.skipped, vec!["prefix_cache/warm_prefill_s".to_string()]);
        // empty baseline: everything skips, gate passes (first run ever)
        let cmp = compare(&cur, &BTreeMap::new(), DEFAULT_THRESHOLD);
        assert!(cmp.regressions.is_empty());
        assert_eq!(cmp.ok.len(), 0);
        assert_eq!(exit_code(&cmp), 0);
    }

    #[test]
    fn trend_point_flattens_all_metrics() {
        let reports = run(BASE_SERVE, 0.02);
        let p = trend_point(&reports);
        assert_eq!(p.get("rev").and_then(|v| v.as_str()), Some("abc1234"));
        assert!(p.get("timestamp").and_then(|v| v.as_f64()).unwrap() > 0.0);
        assert_eq!(
            p.path(&["metrics", "serve_batch/decode_tok_s_pipelined"])
                .and_then(|v| v.as_f64()),
            Some(500.0)
        );
        assert_eq!(
            p.path(&["metrics", "prefix_cache/warm_prefill_s"]).and_then(|v| v.as_f64()),
            Some(0.02)
        );
    }

    #[test]
    fn history_appends_and_caps() {
        let reports = run(BASE_SERVE, 0.02);
        let h = append_point(None, trend_point(&reports));
        assert_eq!(h.get("schema").and_then(|v| v.as_i64()), Some(1));
        assert_eq!(h.get("points").and_then(|v| v.as_arr()).unwrap().len(), 1);
        // malformed prior history is replaced, not crashed on
        let h2 = append_point(Some(s("garbage")), trend_point(&reports));
        assert_eq!(h2.get("points").and_then(|v| v.as_arr()).unwrap().len(), 1);
        // round-trips through the serializer
        let h3 = append_point(
            Some(Json::parse(&h.to_string_compact()).unwrap()),
            trend_point(&reports),
        );
        assert_eq!(h3.get("points").and_then(|v| v.as_arr()).unwrap().len(), 2);
        // the cap drops the oldest points
        let mut h = None;
        for _ in 0..(HISTORY_CAP + 3) {
            h = Some(append_point(h, trend_point(&reports)));
        }
        let pts = h.unwrap();
        assert_eq!(
            pts.get("points").and_then(|v| v.as_arr()).unwrap().len(),
            HISTORY_CAP
        );
    }
}
