//! Prometheus text-exposition rendering for counters, gauges and
//! [`Histogram`]s.
//!
//! The server replies to `{"kind":"stats","format":"prometheus"}` with the
//! rendered registry as a JSON string field (the wire protocol is one JSON
//! object per line, so the exposition body travels escaped and is unescaped
//! client-side). Names are stable, `hae_`-prefixed, and follow Prometheus
//! conventions: counters end in `_total`, histograms expose cumulative
//! `_bucket{le="..."}` series plus `_sum` and `_count`.

use super::hist::Histogram;

fn fmt_f64(v: f64) -> String {
    if v.is_infinite() {
        if v > 0.0 { "+Inf".into() } else { "-Inf".into() }
    } else if v.is_nan() {
        "NaN".into()
    } else if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{}", v)
    }
}

/// `# TYPE name counter` + one sample line.
pub fn counter(out: &mut String, name: &str, help: &str, v: f64) {
    out.push_str(&format!("# HELP {} {}\n# TYPE {} counter\n{} {}\n", name, help, name, name, fmt_f64(v)));
}

/// `# TYPE name gauge` + one sample line.
pub fn gauge(out: &mut String, name: &str, help: &str, v: f64) {
    out.push_str(&format!("# HELP {} {}\n# TYPE {} gauge\n{} {}\n", name, help, name, name, fmt_f64(v)));
}

/// `# TYPE name gauge` + one labeled sample per row
/// (`name{label="key"} v`) — the per-class SLO series use this with
/// `label = "class"`. Keys must need no escaping (they are the fixed
/// `WorkloadKind::wire_name` strings).
pub fn labeled_gauge(out: &mut String, name: &str, help: &str, label: &str, rows: &[(&str, f64)]) {
    out.push_str(&format!("# HELP {} {}\n# TYPE {} gauge\n", name, help, name));
    for (key, v) in rows {
        out.push_str(&format!("{}{{{}=\"{}\"}} {}\n", name, label, key, fmt_f64(*v)));
    }
}

/// Cumulative-bucket histogram exposition. Only buckets at or below the
/// first empty tail are elided to keep the payload proportional to the data
/// actually observed; the mandatory `+Inf` bucket, `_sum` and `_count` are
/// always present.
pub fn histogram(out: &mut String, name: &str, help: &str, h: &Histogram) {
    out.push_str(&format!("# HELP {} {}\n# TYPE {} histogram\n", name, help, name));
    let mut cum = 0u64;
    // index of the last non-empty bucket: everything after it renders the
    // same cumulative count as +Inf, so it can be skipped
    let last_used = h
        .counts()
        .iter()
        .rposition(|c| *c > 0)
        .unwrap_or(0);
    for (i, (edge, c)) in h.edges().iter().zip(h.counts()).enumerate() {
        cum += c;
        if i <= last_used {
            out.push_str(&format!(
                "{}_bucket{{le=\"{}\"}} {}\n",
                name,
                fmt_f64(*edge),
                cum
            ));
        }
    }
    out.push_str(&format!("{}_bucket{{le=\"+Inf\"}} {}\n", name, h.count()));
    out.push_str(&format!("{}_sum {}\n", name, fmt_f64(h.sum())));
    out.push_str(&format!("{}_count {}\n", name, h.count()));
}

/// Lightweight validity check used by tests: every non-comment, non-blank
/// line must be `name{labels} value` or `name value` with a parseable value.
pub fn parses_as_exposition(body: &str) -> bool {
    for line in body.lines() {
        let line = line.trim_end();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((name_part, value_part)) = line.rsplit_once(' ') else {
            return false;
        };
        if name_part.is_empty() {
            return false;
        }
        // metric name: leading identifier, optional {labels} suffix
        let name_end = name_part.find('{').unwrap_or(name_part.len());
        let ident = &name_part[..name_end];
        if ident.is_empty()
            || !ident
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
            || ident.chars().next().unwrap().is_ascii_digit()
        {
            return false;
        }
        if name_end < name_part.len() && !name_part.ends_with('}') {
            return false;
        }
        let ok = value_part.parse::<f64>().is_ok()
            || matches!(value_part, "+Inf" | "-Inf" | "NaN");
        if !ok {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_series_render_and_validate() {
        let mut out = String::new();
        counter(&mut out, "hae_requests_total", "requests submitted", 42.0);
        gauge(&mut out, "hae_queue_depth", "current queue depth", 3.0);
        assert!(out.contains("# TYPE hae_requests_total counter"));
        assert!(out.contains("hae_requests_total 42"));
        assert!(parses_as_exposition(&out), "{}", out);
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let mut h = Histogram::linear(0.0, 10.0, 10);
        for v in [1.0, 1.5, 2.5, 9.0] {
            h.record(v);
        }
        let mut out = String::new();
        histogram(&mut out, "hae_test_ms", "test", &h);
        assert!(out.contains("hae_test_ms_bucket{le=\"+Inf\"} 4"));
        assert!(out.contains("hae_test_ms_sum 14"));
        assert!(out.contains("hae_test_ms_count 4"));
        // cumulative counts never decrease down the bucket list
        let mut prev = 0u64;
        for line in out.lines().filter(|l| l.contains("_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "{}", out);
            prev = v;
        }
        assert!(parses_as_exposition(&out), "{}", out);
    }

    #[test]
    fn validator_rejects_malformed_lines() {
        assert!(!parses_as_exposition("not a metric line at all..!"));
        assert!(!parses_as_exposition("name value_not_numeric"));
        assert!(!parses_as_exposition("1leading_digit 5"));
        assert!(parses_as_exposition("# just a comment\n"));
        assert!(parses_as_exposition("a_b{le=\"0.5\"} 3\n"));
    }
}
