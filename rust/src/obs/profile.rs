//! Serving profiler: contention and queue spans for the threaded core.
//!
//! PR 7 split the engine across threads (scheduler loop, dedicated device
//! thread, connection threads) and left three seams where time can hide:
//! the pool mutex, the bounded device channel (backpressure blocks the
//! *sender*), and the per-step begin/overlap/finish pipeline. This module
//! owns the span histograms for those seams. They live inside `ObsInner`
//! behind the same `Obs::enabled` gate as the trace journal (disabled
//! cost: one relaxed atomic load at the call site) and are recorded
//! alloc-free — fixed-bucket histograms, no labels, no strings on the
//! hot path.
//!
//! Sources:
//! - pool-mutex acquire wait: `cache::paged::lock_profiled`, the timed
//!   wrapper every engine pool-lock site goes through;
//! - device-channel send wait: the engine brackets each device call with
//!   `DeviceHandle::send_wait_us` deltas (the handle itself accumulates
//!   raw always-on atomics — `device::ChannelStats`; the histogram lives
//!   here where the gate is);
//! - step phases: `Scheduler::begin_step`/`finish_step` self-time, the
//!   server's pipelined loop times the overlap window it owns;
//! - device queue depth: sampled once per step by `finish_step`.
//!
//! The raw device-thread *totals* (busy µs, send-wait µs, calls, depth)
//! are deliberately not stored here: the scheduler folds them into its
//! always-on `MetricsRegistry` each step, so `{"kind":"stats"}` and the
//! Prometheus exposition report device health even with tracing off.

use crate::obs::hist::Histogram;
use crate::obs::prometheus;
use crate::util::json::{obj, Json};

/// Mutable profiler state; a field of `ObsInner`, guarded by its mutex.
#[derive(Debug)]
pub struct ProfileSpans {
    /// Wait to acquire the shared page-pool mutex (ms per acquisition).
    pub pool_lock_wait_ms: Histogram,
    /// Wait in `DeviceHandle::send` — nonzero means the bounded device
    /// channel is full and backpressure is blocking the host (ms per call).
    pub device_send_wait_ms: Histogram,
    /// Host time in `Scheduler::begin_step` (gather + submit) per step.
    pub step_begin_ms: Histogram,
    /// Host time spent in the overlap window (replies, ingest drain,
    /// backfill admission) while the device computes, per step.
    pub step_overlap_ms: Histogram,
    /// Host time in `Scheduler::finish_step` (collect + retire) per step.
    pub step_finish_ms: Histogram,
    /// Device-channel depth sampled once per step (calls sent, not yet
    /// completed by the device thread; bounded by `device::QUEUE_DEPTH`).
    pub device_queue_depth: Histogram,
}

impl ProfileSpans {
    pub fn new() -> Self {
        ProfileSpans {
            pool_lock_wait_ms: Histogram::latency_ms(),
            device_send_wait_ms: Histogram::latency_ms(),
            step_begin_ms: Histogram::latency_ms(),
            step_overlap_ms: Histogram::latency_ms(),
            step_finish_ms: Histogram::latency_ms(),
            device_queue_depth: Histogram::linear(0.0, 16.0, 16),
        }
    }

    /// The span block of the `{"kind":"profile"}` wire reply
    /// (`Scheduler::profile_json` adds the envelope and the always-on
    /// device gauges from its metrics registry).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("pool_lock_wait_ms", self.pool_lock_wait_ms.summary_json()),
            ("device_send_wait_ms", self.device_send_wait_ms.summary_json()),
            ("step_begin_ms", self.step_begin_ms.summary_json()),
            ("step_overlap_ms", self.step_overlap_ms.summary_json()),
            ("step_finish_ms", self.step_finish_ms.summary_json()),
            ("device_queue_depth", self.device_queue_depth.summary_json()),
        ])
    }

    /// Append the profiler's span histograms to the Prometheus
    /// exposition. Series names are part of the wire contract
    /// (docs/OBSERVABILITY.md); the device counters/gauges are emitted
    /// by `MetricsRegistry::prometheus_into`, not here.
    pub fn prometheus_into(&self, out: &mut String) {
        prometheus::histogram(
            out,
            "hae_pool_lock_wait_ms",
            "wait to acquire the shared page-pool mutex (ms)",
            &self.pool_lock_wait_ms,
        );
        prometheus::histogram(
            out,
            "hae_device_send_wait_ms",
            "device-channel send wait, backpressure on the host (ms)",
            &self.device_send_wait_ms,
        );
        prometheus::histogram(out, "hae_step_begin_ms", "host time in begin_step per step (ms)", &self.step_begin_ms);
        prometheus::histogram(
            out,
            "hae_step_overlap_ms",
            "host time in the overlap window per step (ms)",
            &self.step_overlap_ms,
        );
        prometheus::histogram(out, "hae_step_finish_ms", "host time in finish_step per step (ms)", &self.step_finish_ms);
        prometheus::histogram(
            out,
            "hae_device_queue_depth_hist",
            "device-channel depth sampled per step",
            &self.device_queue_depth,
        );
    }
}

impl Default for ProfileSpans {
    fn default() -> Self {
        ProfileSpans::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_block_has_all_spans() {
        let mut p = ProfileSpans::new();
        p.pool_lock_wait_ms.record(0.25);
        p.device_queue_depth.record(2.0);
        let j = p.to_json();
        for key in [
            "pool_lock_wait_ms",
            "device_send_wait_ms",
            "step_begin_ms",
            "step_overlap_ms",
            "step_finish_ms",
            "device_queue_depth",
        ] {
            assert!(j.get(key).is_some(), "missing {}", key);
        }
        assert_eq!(j.path(&["pool_lock_wait_ms", "count"]).and_then(|v| v.as_i64()), Some(1));
        assert_eq!(j.path(&["device_queue_depth", "count"]).and_then(|v| v.as_i64()), Some(1));
    }

    #[test]
    fn prometheus_series_present_and_valid() {
        let mut p = ProfileSpans::new();
        p.pool_lock_wait_ms.record(1.5);
        p.device_send_wait_ms.record(0.02);
        let mut out = String::new();
        p.prometheus_into(&mut out);
        assert!(prometheus::parses_as_exposition(&out), "{}", out);
        for series in [
            "hae_pool_lock_wait_ms_bucket",
            "hae_device_send_wait_ms_bucket",
            "hae_step_begin_ms_bucket",
            "hae_step_overlap_ms_bucket",
            "hae_step_finish_ms_bucket",
            "hae_device_queue_depth_hist_bucket",
        ] {
            assert!(out.contains(series), "missing {}", series);
        }
    }
}
