//! Fixed-bucket histograms with bounded memory and whole-run percentiles.
//!
//! The scheduler's original metrics kept raw latency samples in a bounded
//! ring, which silently *drops* the oldest samples — a long-run p99 computed
//! from the survivors is wrong precisely when tail behaviour matters most.
//! A fixed-bucket histogram never drops a sample: every observation lands in
//! one of a pre-computed set of buckets, so memory is exact and constant and
//! percentiles cover the whole run at the cost of bucket-width resolution
//! (log-scale buckets bound the *relative* error instead of the absolute
//! one, which is the right trade for latencies spanning µs to minutes).
//!
//! Recording is alloc-free after construction: `record` touches a pre-sized
//! counts vector via binary search over the edge table and never grows
//! either allocation.

use crate::util::json::{num, obj, Json};

/// Log- or linear-bucketed histogram over `f64` samples.
///
/// Bucket `i` covers `(edges[i-1], edges[i]]`; values at or below the first
/// edge land in bucket 0 and values above the last edge land in a dedicated
/// overflow bucket. Exact `min`/`max`/`sum`/`count` are tracked alongside so
/// extreme quantiles clamp to observed values rather than bucket bounds.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Ascending bucket upper bounds. Never mutated after construction.
    edges: Vec<f64>,
    /// One count per edge plus a trailing overflow bucket.
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    fn from_edges(edges: Vec<f64>) -> Self {
        assert!(edges.len() >= 2, "histogram needs at least two buckets");
        assert!(
            edges.windows(2).all(|w| w[0] < w[1]),
            "histogram edges must be strictly ascending"
        );
        let n = edges.len();
        Histogram {
            edges,
            counts: vec![0; n + 1],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Geometric buckets: `n` edges from `lo` to `hi` inclusive, constant
    /// ratio between consecutive edges (constant relative bucket width).
    pub fn log(lo: f64, hi: f64, n: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && n >= 2);
        let ratio = (hi / lo).powf(1.0 / (n - 1) as f64);
        let mut edges = Vec::with_capacity(n);
        for i in 0..n {
            edges.push(lo * ratio.powi(i as i32));
        }
        // guard against powf drift on the final edge
        *edges.last_mut().unwrap() = hi;
        Histogram::from_edges(edges)
    }

    /// Evenly spaced buckets: `n` edges from `lo + step` to `hi` inclusive.
    pub fn linear(lo: f64, hi: f64, n: usize) -> Self {
        assert!(hi > lo && n >= 2);
        let step = (hi - lo) / n as f64;
        let mut edges = Vec::with_capacity(n);
        for i in 1..=n {
            edges.push(lo + step * i as f64);
        }
        *edges.last_mut().unwrap() = hi;
        Histogram::from_edges(edges)
    }

    /// Latency histogram in milliseconds: 1µs to 10 minutes, ~13% relative
    /// bucket width (160 log-scale buckets).
    pub fn latency_ms() -> Self {
        Histogram::log(1e-3, 6e5, 160)
    }

    /// Fraction histogram over [0, 1] with 2% absolute resolution.
    pub fn unit_fraction() -> Self {
        Histogram::linear(0.0, 1.0, 50)
    }

    /// Count histogram (evicted slots per decision etc.): 1 to 100k,
    /// log-scale.
    pub fn count_scale() -> Self {
        Histogram::log(1.0, 1e5, 60)
    }

    /// Record one sample. Alloc-free. NaN samples are counted in the
    /// overflow bucket but excluded from `sum`/`min`/`max` so one poisoned
    /// value cannot corrupt every derived statistic.
    pub fn record(&mut self, v: f64) {
        self.count += 1;
        if v.is_nan() {
            *self.counts.last_mut().unwrap() += 1;
            return;
        }
        let idx = self.edges.partition_point(|e| *e < v);
        self.counts[idx] += 1;
        self.sum += v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 || self.min.is_infinite() {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 || self.max.is_infinite() {
            0.0
        } else {
            self.max
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Bucket upper bounds (exclusive of the overflow bucket).
    pub fn edges(&self) -> &[f64] {
        &self.edges
    }

    /// Per-bucket counts; last entry is the overflow bucket.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Whole-run quantile estimate: the upper edge of the bucket holding
    /// the rank-`q` sample, clamped to the observed `[min, max]`. Error is
    /// bounded by one bucket width at the quantile.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                let upper = if i < self.edges.len() {
                    self.edges[i]
                } else {
                    self.max()
                };
                return upper.clamp(self.min(), self.max());
            }
        }
        self.max()
    }

    /// Compact JSON summary used by the `phases` block of the stats reply.
    pub fn summary_json(&self) -> Json {
        obj(vec![
            ("count", num(self.count as f64)),
            ("sum", num(self.sum)),
            ("min", num(self.min())),
            ("max", num(self.max())),
            ("p50", num(self.percentile(0.50))),
            ("p95", num(self.percentile(0.95))),
            ("p99", num(self.percentile(0.99))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::percentile as exact_percentile;

    /// The bucket index an exact value falls into (same rule as `record`).
    fn bucket_of(h: &Histogram, v: f64) -> usize {
        h.edges().partition_point(|e| *e < v)
    }

    fn bucket_bounds(h: &Histogram, idx: usize) -> (f64, f64) {
        let lo = if idx == 0 { f64::NEG_INFINITY } else { h.edges()[idx - 1] };
        let hi = if idx < h.edges().len() { h.edges()[idx] } else { f64::INFINITY };
        (lo, hi)
    }

    #[test]
    fn percentiles_within_one_bucket_of_exact() {
        // deterministic long-tailed sample set: 1..=2000 with a heavy tail
        let mut xs: Vec<f64> = (1..=2000).map(|i| i as f64 * 0.37).collect();
        xs.extend((0..40).map(|i| 5_000.0 + 900.0 * i as f64));
        let mut h = Histogram::latency_ms();
        for &x in &xs {
            h.record(x);
        }
        for q in [0.50, 0.95, 0.99] {
            let exact = exact_percentile(&xs, q);
            let got = h.percentile(q);
            // within one bucket: got must land in the exact value's bucket
            // or an immediate neighbour
            let idx = bucket_of(&h, exact);
            let (lo, _) = bucket_bounds(&h, idx.saturating_sub(1));
            let (_, hi) = bucket_bounds(&h, (idx + 1).min(h.edges().len()));
            assert!(
                got >= lo && got <= hi,
                "q={}: exact={} got={} outside one-bucket band [{}, {}]",
                q,
                exact,
                got,
                lo,
                hi
            );
        }
    }

    #[test]
    fn no_allocation_after_construction() {
        let mut h = Histogram::latency_ms();
        let edges_ptr = h.edges().as_ptr();
        let counts_ptr = h.counts().as_ptr();
        let edges_len = h.edges().len();
        let counts_len = h.counts().len();
        for i in 0..10_000 {
            h.record((i % 977) as f64 * 1.3 + 0.001);
        }
        h.record(f64::NAN);
        h.record(1e12); // overflow
        h.record(-5.0); // underflow
        assert_eq!(h.edges().as_ptr(), edges_ptr, "edge table reallocated");
        assert_eq!(h.counts().as_ptr(), counts_ptr, "counts reallocated");
        assert_eq!(h.edges().len(), edges_len);
        assert_eq!(h.counts().len(), counts_len);
        assert_eq!(h.count(), 10_003);
    }

    #[test]
    fn never_drops_samples_unlike_a_ring() {
        // 1M samples into a ~160-bucket histogram: every one is counted
        let mut h = Histogram::latency_ms();
        let n = 1_000_000u64;
        for i in 0..n {
            h.record((i % 10_000) as f64 / 10.0 + 0.01);
        }
        assert_eq!(h.count(), n);
        assert_eq!(h.counts().iter().sum::<u64>(), n);
    }

    #[test]
    fn nan_and_extremes_are_safe() {
        let mut h = Histogram::linear(0.0, 1.0, 10);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(-1.0);
        h.record(0.5);
        assert_eq!(h.count(), 4);
        assert!(h.sum().is_infinite(), "inf lands in sum; nan does not");
        assert_eq!(h.min(), -1.0);
        // empty histogram yields zeros, not NaN
        let e = Histogram::unit_fraction();
        assert_eq!(e.percentile(0.99), 0.0);
        assert_eq!(e.min(), 0.0);
        assert_eq!(e.mean(), 0.0);
    }

    #[test]
    fn monotone_percentiles_and_clamping() {
        let mut h = Histogram::latency_ms();
        for v in [2.0, 2.0, 2.0, 900.0] {
            h.record(v);
        }
        let p50 = h.percentile(0.5);
        let p99 = h.percentile(0.99);
        assert!(p50 <= p99);
        assert!(p99 <= h.max(), "clamped to observed max");
        assert!(h.percentile(0.0) >= h.min());
    }
}
