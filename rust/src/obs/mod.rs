//! Observability layer: request-lifecycle tracing, per-phase histograms,
//! Prometheus exposition and machine-readable bench reports.
//!
//! The serving stack is thread-parallel: the engine loop, the device
//! thread and the server's connection threads all record, so the shared
//! handle is an `Arc<Obs>` with the enabled flag in an atomic and the
//! mutable state (trace ring + histograms) behind one `Mutex`. The hot
//! path stays cheap: a disabled `Obs` costs one relaxed atomic load per
//! call site and never touches the lock, which is what keeps the
//! overhead guardrail in `benches/perf_serve_batch.rs` honest. Recording
//! itself is alloc-free (pre-sized trace ring, `Copy` events,
//! fixed-bucket histograms); the lock is never held across a device
//! call (docs/CONCURRENCY.md).

pub mod bench_report;
pub mod hist;
pub mod profile;
pub mod prometheus;
pub mod trace;
pub mod trend;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

pub use bench_report::BenchReport;
pub use hist::Histogram;
pub use profile::ProfileSpans;
pub use trace::{EvictKind, RetireReason, TraceEvent, TraceJournal, TraceRecord};

use crate::util::json::{num, obj, Json};

/// All mutable engine-side observability state: the trace journal plus
/// the phase histograms the scheduler's metrics registry does not own
/// (it keeps queue-wait/TTFT/e2e, which are scheduler-clock phases).
#[derive(Debug)]
pub struct ObsInner {
    pub trace: TraceJournal,
    /// Cold prefill device time per request (ms).
    pub prefill_ms: Histogram,
    /// Partial warm-start suffix recompute device time per request (ms).
    pub partial_replay_ms: Histogram,
    /// Device time per chunked-extend call (ms).
    pub extend_chunk_ms: Histogram,
    /// Device time per decode step, whole batch (ms).
    pub decode_step_ms: Histogram,
    /// Fraction of vision prompt tokens retained by the prefill decision.
    pub retained_frac_vision: Histogram,
    /// Fraction of text prompt tokens retained by the prefill decision.
    pub retained_frac_text: Histogram,
    /// KV slots evicted per eviction decision (any mechanism).
    pub evicted_per_decision: Histogram,
    /// Threaded-core contention/queue spans (pool mutex, device channel,
    /// step phases) plus folded device-thread gauges.
    pub profile: ProfileSpans,
}

impl ObsInner {
    fn new() -> Self {
        ObsInner {
            trace: TraceJournal::new(),
            prefill_ms: Histogram::latency_ms(),
            partial_replay_ms: Histogram::latency_ms(),
            extend_chunk_ms: Histogram::latency_ms(),
            decode_step_ms: Histogram::latency_ms(),
            retained_frac_vision: Histogram::unit_fraction(),
            retained_frac_text: Histogram::unit_fraction(),
            evicted_per_decision: Histogram::count_scale(),
            profile: ProfileSpans::new(),
        }
    }
}

/// Thread-safe observability handle (see module docs). The enabled gate
/// lives outside the lock so disabled tracing stays off the hot path.
#[derive(Debug)]
pub struct Obs {
    enabled: AtomicBool,
    inner: Mutex<ObsInner>,
}

/// Shared handle: cloned by the engine, scheduler, server and benches.
pub type SharedObs = Arc<Obs>;

impl Obs {
    pub fn new(enabled: bool) -> Self {
        Obs {
            enabled: AtomicBool::new(enabled),
            inner: Mutex::new(ObsInner::new()),
        }
    }

    pub fn shared(enabled: bool) -> SharedObs {
        Arc::new(Obs::new(enabled))
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Direct access to the journal/histograms, ungated — for stats
    /// replies and tests. Never hold this guard across a device call.
    pub fn inner(&self) -> MutexGuard<'_, ObsInner> {
        self.inner.lock().unwrap()
    }

    /// Record one lifecycle event; no-op when tracing is disabled.
    pub fn event(&self, id: u64, ev: TraceEvent) {
        if self.enabled() {
            self.inner().trace.record(id, ev);
        }
    }

    /// Run a recording closure against the histograms under the lock;
    /// no-op when tracing is disabled. The closure must not block.
    pub fn record(&self, f: impl FnOnce(&mut ObsInner)) {
        if self.enabled() {
            f(&mut self.inner());
        }
    }

    /// Engine-phase histogram summaries for the `phases` block of the JSON
    /// stats reply (additive — the flat legacy keys are untouched).
    pub fn phases_json(&self) -> Json {
        let o = self.inner();
        obj(vec![
            ("prefill_ms", o.prefill_ms.summary_json()),
            ("partial_replay_ms", o.partial_replay_ms.summary_json()),
            ("extend_chunk_ms", o.extend_chunk_ms.summary_json()),
            ("decode_step_ms", o.decode_step_ms.summary_json()),
            ("retained_frac_vision", o.retained_frac_vision.summary_json()),
            ("retained_frac_text", o.retained_frac_text.summary_json()),
            ("evicted_per_decision", o.evicted_per_decision.summary_json()),
        ])
    }

    /// The span/gauge block of the `{"kind":"profile"}` wire reply
    /// (`Scheduler::profile_json` wraps it with the reply envelope).
    pub fn profile_json(&self) -> Json {
        self.inner().profile.to_json()
    }

    /// Answer `{"kind":"trace","id":N}` / `{"kind":"trace","last":K}`.
    /// With `id` present, returns that request's retained lifecycle; else
    /// the newest `last` events journal-wide (default 64).
    pub fn trace_json(&self, id: Option<u64>, last: Option<usize>) -> Json {
        let o = self.inner();
        let records = match id {
            Some(rid) => o.trace.for_request(rid),
            None => o.trace.last(last.unwrap_or(64)),
        };
        let events: Vec<Json> = records.iter().map(|r| r.to_json()).collect();
        let mut pairs = vec![
            ("kind", Json::Str("trace".into())),
            ("count", num(events.len() as f64)),
            ("dropped", num(o.trace.total_recorded().saturating_sub(o.trace.len() as u64) as f64)),
        ];
        if let Some(rid) = id {
            pairs.push(("id", num(rid as f64)));
        }
        pairs.push(("events", Json::Arr(events)));
        obj(pairs)
    }

    /// Render the engine-phase histograms in Prometheus exposition format
    /// (the scheduler appends its own registry series).
    pub fn prometheus_body(&self, out: &mut String) {
        let o = self.inner();
        prometheus::histogram(out, "hae_prefill_ms", "cold prefill device time per request (ms)", &o.prefill_ms);
        prometheus::histogram(out, "hae_partial_replay_ms", "warm-start suffix recompute device time per request (ms)", &o.partial_replay_ms);
        prometheus::histogram(out, "hae_extend_chunk_ms", "device time per chunked-extend call (ms)", &o.extend_chunk_ms);
        prometheus::histogram(out, "hae_decode_step_ms", "device time per decode step (ms)", &o.decode_step_ms);
        prometheus::histogram(out, "hae_retained_frac_vision", "fraction of vision prompt tokens retained at prefill", &o.retained_frac_vision);
        prometheus::histogram(out, "hae_retained_frac_text", "fraction of text prompt tokens retained at prefill", &o.retained_frac_text);
        prometheus::histogram(out, "hae_evicted_slots_per_decision", "KV slots evicted per eviction decision", &o.evicted_per_decision);
        prometheus::counter(out, "hae_trace_events_total", "lifecycle trace events recorded", o.trace.total_recorded() as f64);
        o.profile.prometheus_into(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_obs_records_nothing() {
        let o = Obs::new(false);
        o.event(1, TraceEvent::Enqueued);
        o.event(1, TraceEvent::DecodeStep);
        o.record(|i| i.decode_step_ms.record(1.0));
        assert_eq!(o.inner().trace.total_recorded(), 0);
        assert_eq!(o.inner().decode_step_ms.count(), 0);
        o.set_enabled(true);
        o.event(1, TraceEvent::Enqueued);
        assert_eq!(o.inner().trace.total_recorded(), 1);
    }

    #[test]
    fn trace_json_by_id_and_by_last() {
        let o = Obs::new(true);
        o.event(1, TraceEvent::Enqueued);
        o.event(2, TraceEvent::Enqueued);
        o.event(1, TraceEvent::Retired { reason: RetireReason::Completed });
        let by_id = o.trace_json(Some(1), None);
        assert_eq!(by_id.get("count").and_then(|v| v.as_i64()), Some(2));
        assert_eq!(by_id.get("id").and_then(|v| v.as_i64()), Some(1));
        let ev = by_id.get("events").unwrap().as_arr().unwrap();
        assert_eq!(ev[0].get("event").and_then(|v| v.as_str()), Some("enqueued"));
        assert_eq!(ev[1].get("event").and_then(|v| v.as_str()), Some("retired"));
        let last = o.trace_json(None, Some(2));
        assert_eq!(last.get("count").and_then(|v| v.as_i64()), Some(2));
        assert_eq!(last.get("dropped").and_then(|v| v.as_i64()), Some(0));
    }

    #[test]
    fn phases_json_has_all_histograms() {
        let o = Obs::new(true);
        o.record(|i| i.prefill_ms.record(12.0));
        let p = o.phases_json();
        for key in [
            "prefill_ms",
            "partial_replay_ms",
            "extend_chunk_ms",
            "decode_step_ms",
            "retained_frac_vision",
            "retained_frac_text",
            "evicted_per_decision",
        ] {
            assert!(p.get(key).is_some(), "missing {}", key);
        }
        assert_eq!(p.path(&["prefill_ms", "count"]).and_then(|v| v.as_i64()), Some(1));
    }

    #[test]
    fn prometheus_body_is_valid_exposition() {
        let o = Obs::new(true);
        o.record(|i| i.decode_step_ms.record(0.5));
        o.record(|i| i.evicted_per_decision.record(8.0));
        let mut out = String::new();
        o.prometheus_body(&mut out);
        assert!(prometheus::parses_as_exposition(&out), "{}", out);
        assert!(out.contains("hae_decode_step_ms_bucket"));
    }

    #[test]
    fn shared_obs_is_recordable_from_many_threads() {
        use std::thread;
        let o = Obs::shared(true);
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let o = Arc::clone(&o);
            handles.push(thread::spawn(move || {
                for i in 0..50 {
                    o.event(t * 1000 + i, TraceEvent::Enqueued);
                    o.record(|inner| inner.decode_step_ms.record(0.1));
                }
            }));
        }
        for h in handles {
            h.join().expect("obs recorder panicked");
        }
        assert_eq!(o.inner().trace.total_recorded(), 200);
        assert_eq!(o.inner().decode_step_ms.count(), 200);
    }
}
