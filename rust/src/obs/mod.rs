//! Observability layer: request-lifecycle tracing, per-phase histograms,
//! Prometheus exposition and machine-readable bench reports.
//!
//! The serving stack is single-threaded around a PJRT client that is not
//! `Send`, so the shared handle is an `Rc<RefCell<Obs>>` (the same pattern
//! as `SharedPagePool`): the engine owns the instance, the scheduler clones
//! the handle, and the server reaches it through the scheduler's stats
//! methods. Recording on the hot path is alloc-free (pre-sized trace ring,
//! `Copy` events, fixed-bucket histograms) and globally gated by `enabled`
//! so the overhead guardrail in `benches/perf_serve_batch.rs` can measure
//! tracing on vs off.

pub mod bench_report;
pub mod hist;
pub mod prometheus;
pub mod trace;

use std::cell::RefCell;
use std::rc::Rc;

pub use bench_report::BenchReport;
pub use hist::Histogram;
pub use trace::{EvictKind, RetireReason, TraceEvent, TraceJournal, TraceRecord};

use crate::util::json::{num, obj, Json};

/// All engine-side observability state: the trace journal plus the phase
/// histograms the scheduler's metrics registry does not own (it keeps
/// queue-wait/TTFT/e2e, which are scheduler-clock phases).
#[derive(Debug)]
pub struct Obs {
    enabled: bool,
    pub trace: TraceJournal,
    /// Cold prefill device time per request (ms).
    pub prefill_ms: Histogram,
    /// Partial warm-start suffix recompute device time per request (ms).
    pub partial_replay_ms: Histogram,
    /// Device time per chunked-extend call (ms).
    pub extend_chunk_ms: Histogram,
    /// Device time per decode step, whole batch (ms).
    pub decode_step_ms: Histogram,
    /// Fraction of vision prompt tokens retained by the prefill decision.
    pub retained_frac_vision: Histogram,
    /// Fraction of text prompt tokens retained by the prefill decision.
    pub retained_frac_text: Histogram,
    /// KV slots evicted per eviction decision (any mechanism).
    pub evicted_per_decision: Histogram,
}

/// Single-threaded shared handle (see module docs).
pub type SharedObs = Rc<RefCell<Obs>>;

impl Obs {
    pub fn new(enabled: bool) -> Self {
        Obs {
            enabled,
            trace: TraceJournal::new(),
            prefill_ms: Histogram::latency_ms(),
            partial_replay_ms: Histogram::latency_ms(),
            extend_chunk_ms: Histogram::latency_ms(),
            decode_step_ms: Histogram::latency_ms(),
            retained_frac_vision: Histogram::unit_fraction(),
            retained_frac_text: Histogram::unit_fraction(),
            evicted_per_decision: Histogram::count_scale(),
        }
    }

    pub fn shared(enabled: bool) -> SharedObs {
        Rc::new(RefCell::new(Obs::new(enabled)))
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
    }

    /// Record one lifecycle event; no-op when tracing is disabled.
    pub fn event(&mut self, id: u64, ev: TraceEvent) {
        if self.enabled {
            self.trace.record(id, ev);
        }
    }

    /// Engine-phase histogram summaries for the `phases` block of the JSON
    /// stats reply (additive — the flat legacy keys are untouched).
    pub fn phases_json(&self) -> Json {
        obj(vec![
            ("prefill_ms", self.prefill_ms.summary_json()),
            ("partial_replay_ms", self.partial_replay_ms.summary_json()),
            ("extend_chunk_ms", self.extend_chunk_ms.summary_json()),
            ("decode_step_ms", self.decode_step_ms.summary_json()),
            ("retained_frac_vision", self.retained_frac_vision.summary_json()),
            ("retained_frac_text", self.retained_frac_text.summary_json()),
            ("evicted_per_decision", self.evicted_per_decision.summary_json()),
        ])
    }

    /// Answer `{"kind":"trace","id":N}` / `{"kind":"trace","last":K}`.
    /// With `id` present, returns that request's retained lifecycle; else
    /// the newest `last` events journal-wide (default 64).
    pub fn trace_json(&self, id: Option<u64>, last: Option<usize>) -> Json {
        let records = match id {
            Some(rid) => self.trace.for_request(rid),
            None => self.trace.last(last.unwrap_or(64)),
        };
        let events: Vec<Json> = records.iter().map(|r| r.to_json()).collect();
        let mut pairs = vec![
            ("kind", Json::Str("trace".into())),
            ("count", num(events.len() as f64)),
            ("dropped", num(self.trace.total_recorded().saturating_sub(self.trace.len() as u64) as f64)),
        ];
        if let Some(rid) = id {
            pairs.push(("id", num(rid as f64)));
        }
        pairs.push(("events", Json::Arr(events)));
        obj(pairs)
    }

    /// Render the engine-phase histograms in Prometheus exposition format
    /// (the scheduler appends its own registry series).
    pub fn prometheus_body(&self, out: &mut String) {
        prometheus::histogram(out, "hae_prefill_ms", "cold prefill device time per request (ms)", &self.prefill_ms);
        prometheus::histogram(out, "hae_partial_replay_ms", "warm-start suffix recompute device time per request (ms)", &self.partial_replay_ms);
        prometheus::histogram(out, "hae_extend_chunk_ms", "device time per chunked-extend call (ms)", &self.extend_chunk_ms);
        prometheus::histogram(out, "hae_decode_step_ms", "device time per decode step (ms)", &self.decode_step_ms);
        prometheus::histogram(out, "hae_retained_frac_vision", "fraction of vision prompt tokens retained at prefill", &self.retained_frac_vision);
        prometheus::histogram(out, "hae_retained_frac_text", "fraction of text prompt tokens retained at prefill", &self.retained_frac_text);
        prometheus::histogram(out, "hae_evicted_slots_per_decision", "KV slots evicted per eviction decision", &self.evicted_per_decision);
        prometheus::counter(out, "hae_trace_events_total", "lifecycle trace events recorded", self.trace.total_recorded() as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_obs_records_nothing() {
        let mut o = Obs::new(false);
        o.event(1, TraceEvent::Enqueued);
        o.event(1, TraceEvent::DecodeStep);
        assert_eq!(o.trace.total_recorded(), 0);
        o.set_enabled(true);
        o.event(1, TraceEvent::Enqueued);
        assert_eq!(o.trace.total_recorded(), 1);
    }

    #[test]
    fn trace_json_by_id_and_by_last() {
        let mut o = Obs::new(true);
        o.event(1, TraceEvent::Enqueued);
        o.event(2, TraceEvent::Enqueued);
        o.event(1, TraceEvent::Retired { reason: RetireReason::Completed });
        let by_id = o.trace_json(Some(1), None);
        assert_eq!(by_id.get("count").and_then(|v| v.as_i64()), Some(2));
        assert_eq!(by_id.get("id").and_then(|v| v.as_i64()), Some(1));
        let ev = by_id.get("events").unwrap().as_arr().unwrap();
        assert_eq!(ev[0].get("event").and_then(|v| v.as_str()), Some("enqueued"));
        assert_eq!(ev[1].get("event").and_then(|v| v.as_str()), Some("retired"));
        let last = o.trace_json(None, Some(2));
        assert_eq!(last.get("count").and_then(|v| v.as_i64()), Some(2));
        assert_eq!(last.get("dropped").and_then(|v| v.as_i64()), Some(0));
    }

    #[test]
    fn phases_json_has_all_histograms() {
        let mut o = Obs::new(true);
        o.prefill_ms.record(12.0);
        let p = o.phases_json();
        for key in [
            "prefill_ms",
            "partial_replay_ms",
            "extend_chunk_ms",
            "decode_step_ms",
            "retained_frac_vision",
            "retained_frac_text",
            "evicted_per_decision",
        ] {
            assert!(p.get(key).is_some(), "missing {}", key);
        }
        assert_eq!(p.path(&["prefill_ms", "count"]).and_then(|v| v.as_i64()), Some(1));
    }

    #[test]
    fn prometheus_body_is_valid_exposition() {
        let mut o = Obs::new(true);
        o.decode_step_ms.record(0.5);
        o.evicted_per_decision.record(8.0);
        let mut out = String::new();
        o.prometheus_body(&mut out);
        assert!(prometheus::parses_as_exposition(&out), "{}", out);
        assert!(out.contains("hae_decode_step_ms_bucket"));
    }
}
