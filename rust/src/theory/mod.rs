//! Executable forms of the paper's theoretical results.
//!
//! * Theorem 2.1 (Cache Information Integrity): under an exponential decay
//!   model S(t) = S₀(1−λ)^t, the eviction threshold
//!   k ≤ log(ε / Attn_max) / log(1−λ) keeps the total evicted loss < ε.
//! * Corollary 2.1 (Error Upper Bound): the realized DDES loss is bounded
//!   by the greedy (H2O) loss — the sum of the d lowest scores — because
//!   deferring eviction lets scores keep accumulating evidence before the
//!   decision is finalized.
//!
//! These are checked against *measured* traces in rust/tests/theory.rs and
//! regenerated as a table by benches/theory_bounds.rs.

use crate::coordinator::EvictionEvent;

/// Theorem 2.1: maximum eviction threshold k for loss budget `eps`.
///
/// `attn_max` is the largest initial attention score among eviction
/// candidates; `lambda` the fitted decay rate. Returns None when the bound
/// is vacuous (eps ≥ attn_max, i.e. any k works) or undefined (λ = 0).
pub fn integrity_bound(eps: f64, attn_max: f64, lambda: f64) -> Option<f64> {
    if eps <= 0.0 || attn_max <= 0.0 || lambda <= 0.0 || lambda >= 1.0 {
        return None;
    }
    if eps >= attn_max {
        return None; // any k satisfies the bound
    }
    Some((eps / attn_max).ln() / (1.0 - lambda).ln())
}

/// Worst-case single-token loss after surviving k evictions under the
/// decay model (the quantity Theorem 2.1 bounds by ε).
pub fn worst_case_loss(attn_max: f64, lambda: f64, k: f64) -> f64 {
    attn_max * (1.0 - lambda).powf(k)
}

/// Geometric-series total loss over k evictions spaced Δt = 1 apart
/// (the theorem's Discussion paragraph).
pub fn geometric_total_loss(attn_max: f64, lambda: f64, k: usize) -> f64 {
    if lambda <= 0.0 {
        return attn_max * k as f64;
    }
    let q = 1.0 - lambda;
    attn_max * q * (1.0 - q.powi(k as i32)) / lambda
}

/// Realized eviction loss of a run: the sum of cumulative-at-eviction
/// scores of every evicted slot (the Σ εᵢ of Corollary 2.1).
pub fn realized_loss(events: &[EvictionEvent]) -> f64 {
    events
        .iter()
        .flat_map(|e| e.victims.iter())
        .map(|&(_, score, _)| score as f64)
        .sum()
}

/// Greedy bound for a run that evicted `d` slots in total: the sum of the
/// `d` lowest final scores available in `candidate_scores` (Low_d(S₁)).
pub fn greedy_bound(candidate_scores: &[f32], d: usize) -> f64 {
    let mut v: Vec<f32> = candidate_scores.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    v.iter().take(d).map(|&s| s as f64).sum()
}

/// Corollary 2.1 check on one trace: DDES realized loss ≤ greedy realized
/// loss for the same number of evictions, where both are measured against
/// the same score stream. Returns (ddes_loss, greedy_loss).
pub fn corollary_check(
    ddes_events: &[EvictionEvent],
    greedy_events: &[EvictionEvent],
) -> (f64, f64) {
    (realized_loss(ddes_events), realized_loss(greedy_events))
}

/// Forward loss of an eviction schedule — the quantity Corollary 2.1
/// actually bounds: the attention mass each evicted token *would have
/// received* after its eviction step, measured on the full-cache reference
/// trace (`ref_trace[step]` = (position, mean score) snapshots from a
/// teacher-forced full-cache run of the same script).
pub fn forward_loss(events: &[EvictionEvent], ref_trace: &[Vec<(i32, f32)>]) -> f64 {
    let mut total = 0.0f64;
    for e in events {
        for &(pos, _, _) in &e.victims {
            for snap in ref_trace.iter().skip(e.step + 1) {
                if let Some(&(_, s)) = snap.iter().find(|&&(p, _)| p == pos) {
                    total += s as f64;
                }
            }
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_monotone_in_eps() {
        let k1 = integrity_bound(0.01, 1.0, 0.2).unwrap();
        let k2 = integrity_bound(0.001, 1.0, 0.2).unwrap();
        // smaller allowable loss → larger k (tokens must decay longer
        // before eviction is safe)
        assert!(k2 > k1);
    }

    #[test]
    fn bound_consistency_with_worst_case() {
        let (eps, amax, lambda) = (0.01, 0.8, 0.15);
        let k = integrity_bound(eps, amax, lambda).unwrap();
        // at exactly k the worst-case loss equals eps
        let loss = worst_case_loss(amax, lambda, k);
        assert!((loss - eps).abs() < 1e-9, "loss {}", loss);
        // beyond k it is smaller
        assert!(worst_case_loss(amax, lambda, k + 1.0) < eps);
    }

    #[test]
    fn vacuous_and_undefined_cases() {
        assert!(integrity_bound(1.0, 0.5, 0.2).is_none()); // eps ≥ attn_max
        assert!(integrity_bound(0.01, 0.5, 0.0).is_none()); // λ = 0
        assert!(integrity_bound(-1.0, 0.5, 0.2).is_none());
    }

    #[test]
    fn geometric_total_bounded() {
        let total = geometric_total_loss(0.5, 0.3, 50);
        // closed form limit: amax·q/λ = 0.5·0.7/0.3
        assert!(total <= 0.5 * 0.7 / 0.3 + 1e-9);
    }

    #[test]
    fn greedy_bound_is_lowest_d() {
        let scores = [0.5f32, 0.1, 0.9, 0.2];
        assert!((greedy_bound(&scores, 2) - 0.3).abs() < 1e-6);
    }

    #[test]
    fn realized_loss_sums_victims() {
        let events = vec![crate::coordinator::EvictionEvent {
            step: 3,
            victims: vec![(0, 0.25, true), (5, 0.5, true)],
        }];
        assert!((realized_loss(&events) - 0.75).abs() < 1e-9);
    }
}
