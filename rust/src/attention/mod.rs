//! Attention-statistics substrate: the observation machinery behind the
//! paper's Figs. 2/3/5 and the decay-model fit Theorem 2.1 needs.

pub mod stats;

pub use stats::{
    cumulative_variance_split, decay_rate_fit, sparsity_from_probs, VarianceSplit,
};
