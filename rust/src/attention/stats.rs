//! Host-side attention statistics.
//!
//! The analysis executable already reduces per-layer sparsity and DAP
//! column statistics in-graph; this module adds the cross-sample
//! aggregations the figures plot (variance of cumulative scores split by
//! modality, Fig. 2) and the exponential decay-rate fit λ that
//! Theorem 2.1's bound consumes.

use crate::util::stats::{linear_fit, variance};

/// Fig. 2: variance of cumulative attention scores, split by token
/// modality, pooled across samples.
#[derive(Debug, Clone, Default)]
pub struct VarianceSplit {
    pub visual_var: f64,
    pub text_var: f64,
    pub visual_mean: f64,
    pub text_mean: f64,
    pub n_visual: usize,
    pub n_text: usize,
}

/// `colsum` is a layer's per-column cumulative attention (analysis
/// artifact); pool scores by modality and compute variances.
pub fn cumulative_variance_split(
    samples: &[(Vec<f32>, Vec<bool>, usize)], // (colsum, is_vision, n_tokens)
) -> VarianceSplit {
    let mut vis = Vec::new();
    let mut txt = Vec::new();
    for (colsum, is_vision, n_tokens) in samples {
        for i in 0..*n_tokens {
            if is_vision[i] {
                vis.push(colsum[i] as f64);
            } else {
                txt.push(colsum[i] as f64);
            }
        }
    }
    VarianceSplit {
        visual_var: variance(&vis),
        text_var: variance(&txt),
        visual_mean: crate::util::stats::mean(&vis),
        text_mean: crate::util::stats::mean(&txt),
        n_visual: vis.len(),
        n_text: txt.len(),
    }
}

/// Sparsity rate of a probability matrix region (paper Eq. 7), computed
/// host-side from the analysis artifact's layer-0 probs. `probs` is
/// `[H, S, S]`; only the causal, valid region is counted.
pub fn sparsity_from_probs(
    probs: &[f32],
    n_heads: usize,
    s: usize,
    is_vision: &[bool],
    n_tokens: usize,
    eps: f32,
) -> (f64, f64, f64) {
    let mut counts = [0u64; 3]; // overall, visual, text (small entries)
    let mut totals = [0u64; 3];
    for i in 0..n_tokens {
        for j in 0..=i.min(n_tokens - 1) {
            // head-mean
            let mut p = 0.0f32;
            for h in 0..n_heads {
                p += probs[(h * s + i) * s + j];
            }
            p /= n_heads as f32;
            let small = p <= eps;
            totals[0] += 1;
            if small {
                counts[0] += 1;
            }
            let m = if is_vision[j] { 1 } else { 2 };
            totals[m] += 1;
            if small {
                counts[m] += 1;
            }
        }
    }
    let rate = |c: u64, t: u64| if t == 0 { 0.0 } else { c as f64 / t as f64 };
    (
        rate(counts[0], totals[0]),
        rate(counts[1], totals[1]),
        rate(counts[2], totals[2]),
    )
}

/// Fit the exponential decay rate λ of per-step attention scores:
/// S(t) = S₀·(1−λ)^t  ⇒  ln S(t) linear in t with slope ln(1−λ).
///
/// `score_series` is a sequence of per-step scores for one slot (or a mean
/// over slots). Returns λ ∈ [0, 1).
pub fn decay_rate_fit(score_series: &[f64]) -> f64 {
    let pts: Vec<(f64, f64)> = score_series
        .iter()
        .enumerate()
        .filter(|(_, &s)| s > 1e-12)
        .map(|(t, &s)| (t as f64, s.ln()))
        .collect();
    if pts.len() < 2 {
        return 0.0;
    }
    let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
    let (slope, _) = linear_fit(&xs, &ys);
    // slope = ln(1 - λ)
    (1.0 - slope.exp()).clamp(0.0, 0.999_999)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variance_split_separates_modalities() {
        // visual scores tightly clustered, text scores spread
        let colsum = vec![0.1, 0.1, 0.1, 0.0, 0.5, 1.0];
        let is_vision = vec![true, true, true, false, false, false];
        let v = cumulative_variance_split(&[(colsum, is_vision, 6)]);
        assert!(v.text_var > v.visual_var);
        assert_eq!(v.n_visual, 3);
        assert_eq!(v.n_text, 3);
    }

    #[test]
    fn decay_fit_recovers_lambda() {
        let lambda = 0.2f64;
        let series: Vec<f64> = (0..20).map(|t| 0.9 * (1.0 - lambda).powi(t)).collect();
        let fit = decay_rate_fit(&series);
        assert!((fit - lambda).abs() < 1e-6, "fit {}", fit);
    }

    #[test]
    fn decay_fit_handles_flat() {
        let series = vec![0.5; 10];
        let fit = decay_rate_fit(&series);
        assert!(fit.abs() < 1e-9);
    }

    #[test]
    fn sparsity_counts_causal_region() {
        // 1 head, s=2, both tokens valid text; probs row0=[1,0], row1=[0.5,0.5]
        let probs = vec![1.0, 0.0, 0.5, 0.5];
        let (overall, vis, txt) =
            sparsity_from_probs(&probs, 1, 2, &[false, false], 2, 1e-4);
        // causal entries: (0,0)=1, (1,0)=0.5, (1,1)=0.5 → none small
        assert_eq!(overall, 0.0);
        assert_eq!(vis, 0.0);
        assert_eq!(txt, 0.0);
        let (overall, _, _) =
            sparsity_from_probs(&probs, 1, 2, &[false, false], 2, 0.6);
        // entries ≤ 0.6: the two 0.5s → 2/3
        assert!((overall - 2.0 / 3.0).abs() < 1e-9);
    }
}
