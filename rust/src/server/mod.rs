//! JSON-lines TCP front end.
//!
//! Protocol: one JSON object per line.
//!   request:  {"id": 1, "kind": "story"|"qa"|"video"|"mixed",
//!              "max_new": 64, "seed": 123}
//!            (requests are synthesized server-side from the workload
//!             generators — the "tokenizer + vision encoder" of this
//!             system; a "seed" field makes the synthesized prompt
//!             reproducible across connections)
//!   shared-image QA: {"id": 1, "kind": "qa", "image_seed": 7,
//!                     "q": "color"|"shape"}
//!            (the image is drawn from "image_seed" alone, so every
//!             request naming the same image carries a bit-identical
//!             visual prefix — the engine's radix-tree prefix cache
//!             serves repeat questions without recomputing prefill)
//!   multi-turn dialog: {"id": 1, "kind": "qa", "image_seed": 7,
//!                       "turn": 3}
//!            (turn T's prompt replays turns 0..T's Q/A history and ends
//!             at a fresh question — every turn is a distinct prompt, so
//!             exact reuse is impossible; the engine serves them through
//!             partial-prefix warm starts: the image's cached KV is
//!             adopted copy-on-write and only the dialog text is
//!             recomputed, with the pruning decision re-run per request)
//!   stats:    {"kind": "stats"} → scheduler metrics snapshot
//!             (queue depth, TTFT/e2e percentiles, lanes histogram,
//!              admission rejections, aggregate KV bytes, plus a nested
//!              "phases" block of per-phase histogram summaries)
//!   prometheus: {"kind": "stats", "format": "prometheus"} →
//!             {"kind":"stats","format":"prometheus","body":"..."} where
//!             body is the full metric set in Prometheus text exposition
//!             format (scrapers unwrap the one JSON field)
//!   trace:    {"kind": "trace", "id": N} → request N's retained
//!             lifecycle events; {"kind": "trace", "last": K} → the
//!             newest K events journal-wide (default 64). Reply:
//!             {"kind":"trace","count":N,"dropped":N,"events":[
//!               {"id":N,"at_us":T,"event":"enqueued"|...}, ...]}
//!   profile:  {"kind": "profile"} → serving-profiler snapshot: span
//!             histogram summaries for the threaded core's contention
//!             seams (pool-mutex wait, device-channel send wait, step
//!             begin/overlap/finish, sampled device queue depth) plus
//!             the always-on device-thread totals. Reply:
//!             {"kind":"profile","tracing":bool,"spans":{...},
//!              "device":{"busy_us":...,"send_wait_us":...,"calls":...,
//!              "queue_depth":...,"peak_queue_depth":...}}
//!   response: {"id": 1, "tokens": [...], "text": "...",
//!              "queue_ms": ..., "prefill_ms": ..., "extend_ms": ...,
//!              "extend_calls": N, "decode_ms": ..., "steps": N,
//!              "pruned": N, "evicted": N, "peak_kv_kib": N}
//!            (a warm prefix hit keeps the established prefill_ms == 0
//!             semantics; extend_ms/extend_calls expose the partial
//!             warm-start suffix recompute instead)
//!   error:    {"id": 1, "error": "..."} (id echoed whenever the request
//!             line carried one)
//!
//! Architecture: acceptor + per-connection reader/writer threads feed a
//! channel into the *router loop* on the caller's thread
//! (`router::router_loop` — consistent-hash placement on the request's
//! vision-segment content hash, plus shed/spill; see docs/SERVING.md).
//! The router forwards each line to one of N replica threads
//! (`hae-replica-<i>`), and each replica runs the scheduler loop over
//! its own engine, `PagePool`, prefix cache and ingest mailbox. With
//! `--replicas 1` (the default) the router is a transparent passthrough
//! and the wire behavior is the single-engine server's.
//!
//! Device work runs on each engine's dedicated device thread (the PJRT
//! client is `!Send` — see `device::spawn` and docs/CONCURRENCY.md),
//! which is what lets the scheduler loop pipeline: with
//! `engine_threads > 1` each round submits the decode batch, then spends
//! the device window delivering finished replies, draining the ingest
//! mailbox and backfilling free lanes (admission + prefill of the next
//! candidates) before collecting the step. `engine_threads == 1` keeps
//! the strictly sequential round — the measured baseline in
//! `benches/perf_serve_batch.rs`. Either way, requests join free decode
//! lanes mid-flight under KV-budget admission control, and each response
//! flows back through its connection's channel the moment that request
//! finishes — short requests are never serialized behind long
//! generations admitted earlier.
//!
//! Shutdown is a drain, not an abort: the router broadcasts the shutdown
//! line to every replica, the flag flips, connection readers notice
//! within one read-timeout, the acceptor is popped out of `accept` by a
//! self-connection and *joins* every connection thread, and
//! `serve_replicas_on` joins the acceptor and every replica thread — so
//! when it returns, no server thread is left running and every device
//! thread has been joined by its engine's drop at replica exit.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::Engine;
use crate::model::{vocab, ModelMeta};
use crate::router::{router_loop, ReplicaHealth, ReplicaLink, RouterConfig, RouterPolicy};
use crate::scheduler::{SchedOutcome, SchedPolicy, Scheduler, SchedulerConfig, SloTable};
use crate::util::json::{num, obj, s, Json};
use crate::workload::{RequestBuilder, StoryGrammar, WorkloadKind};

pub struct ServerConfig {
    pub addr: String,
    /// max requests waiting for admission before graceful rejection
    pub queue_depth: usize,
    /// aggregate live-KV budget in bytes (None → engine ceiling),
    /// applied per replica
    pub kv_budget: Option<usize>,
    pub sched_policy: SchedPolicy,
    /// 1 = strictly sequential scheduler rounds (submit and collect
    /// back-to-back — the measured baseline); ≥2 = pipelined rounds that
    /// overlap host work with the device window. Per replica there is
    /// always exactly one scheduler thread and one device thread; this
    /// selects the overlap discipline between them.
    pub engine_threads: usize,
    /// per-class latency SLO targets (`--slo class=ttft_ms:e2e_ms,...`);
    /// empty = no attainment accounting
    pub slo: SloTable,
    /// how the router places workload lines across replicas
    /// (`--router affinity|round_robin`; round_robin is the bench
    /// control arm)
    pub router_policy: RouterPolicy,
    /// shed with the typed `{"kind":"error","reason":"shed"}` reply when
    /// the target replica's admission depth reaches this bound
    /// (`--shed-queue N`; None = never shed)
    pub shed_queue: Option<usize>,
    /// spill affinity traffic to the ring's second choice when the
    /// primary's pool occupancy is at or above this fraction
    /// (`--spill-occupancy F`; None = never spill)
    pub spill_occupancy: Option<f64>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:8472".into(),
            queue_depth: 64,
            kv_budget: None,
            sched_policy: SchedPolicy::Fifo,
            engine_threads: 2,
            slo: SloTable::default(),
            router_policy: RouterPolicy::Affinity,
            shed_queue: None,
            spill_occupancy: None,
        }
    }
}

/// One raw request line plus the channel its reply goes back on — the
/// unit of work between connection threads, the router, and each
/// replica's scheduler loop.
pub(crate) struct Job {
    pub(crate) line: String,
    pub(crate) reply: mpsc::Sender<String>,
}

/// Scheduler tag: everything needed to answer a request later.
struct JobTag {
    id: i64,
    reply: mpsc::Sender<String>,
}

/// Turn one parsed request object into a workload Request (synthesized).
/// A "seed" field draws the prompt from a fresh builder at that seed so
/// identical request lines produce identical prompts on any connection;
/// without it the connection-shared builder stream is used.
pub(crate) fn synthesize(
    j: &Json,
    meta: &ModelMeta,
    grammar: &StoryGrammar,
    builder: &mut RequestBuilder,
) -> Result<(i64, crate::workload::Request)> {
    let id = j.get("id").and_then(|v| v.as_i64()).unwrap_or(0);
    let kind_str = j
        .get("kind")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing kind (accepted: {})", WorkloadKind::accepted()))?;
    let kind = WorkloadKind::parse(kind_str).ok_or_else(|| {
        anyhow!("unknown kind '{}' (accepted: {})", kind_str, WorkloadKind::accepted())
    })?;
    let mut req = match (kind, j.get("image_seed").and_then(|v| v.as_i64())) {
        (WorkloadKind::Understanding, Some(iseed)) => {
            // shared-image QA: the image depends on image_seed alone, so
            // co-referencing requests share a bit-identical visual prefix.
            // "turn" selects a multi-turn dialog prompt (distinct per
            // turn — served via partial-prefix warm starts); "q" the
            // single-turn question
            if let Some(turn) = j.get("turn").and_then(|v| v.as_i64()) {
                if turn < 0 {
                    bail!("turn must be >= 0, got {}", turn);
                }
                builder.qa_dialog_turn(iseed as u64, turn as usize)
            } else {
                let ask_color = match j.get("q").and_then(|v| v.as_str()) {
                    None | Some("color") => true,
                    Some("shape") => false,
                    Some(other) => {
                        bail!("unknown q '{}' (accepted: color, shape)", other)
                    }
                };
                builder.understanding_shared(iseed as u64, ask_color)
            }
        }
        _ => match j.get("seed").and_then(|v| v.as_i64()) {
            Some(seed) => RequestBuilder::new(meta, grammar, seed as u64).make(kind),
            None => builder.make(kind),
        },
    };
    if let Some(mx) = j.get("max_new").and_then(|v| v.as_usize()) {
        req.max_new_tokens = mx;
        req.min_new_tokens = req.min_new_tokens.min(mx);
    }
    // carry the wire id into the engine so trace-journal events are
    // queryable by the id the client knows (builders assign synthetic ids)
    if id >= 0 {
        req.id = id as u64;
    }
    Ok((id, req))
}

fn respond(id: i64, ar: &crate::coordinator::ActiveRequest) -> String {
    let text: Vec<String> = ar.generated.iter().map(|&t| vocab::describe(t)).collect();
    obj(vec![
        ("id", num(id as f64)),
        (
            "tokens",
            Json::Arr(ar.generated.iter().map(|&t| num(t as f64)).collect()),
        ),
        ("text", s(&text.join(" "))),
        ("queue_ms", num(ar.stats.queue_s * 1000.0)),
        ("prefill_ms", num(ar.stats.prefill_s * 1000.0)),
        ("extend_ms", num(ar.stats.extend_s * 1000.0)),
        ("extend_calls", num(ar.stats.extend_calls as f64)),
        ("decode_ms", num(ar.stats.decode_s * 1000.0)),
        ("steps", num(ar.stats.steps as f64)),
        ("pruned", num(ar.stats.pruned_at_prefill as f64)),
        ("evicted", num(ar.stats.evicted_at_decode as f64)),
        ("peak_kv_kib", num(ar.stats.peak_kv_bytes as f64 / 1024.0)),
    ])
    .to_string_compact()
}

/// JSON error object, escaped through the serializer and echoing the
/// request id when one is known.
pub(crate) fn error_reply(id: Option<i64>, err: &str) -> String {
    let mut fields = vec![("error", s(err))];
    if let Some(id) = id {
        fields.push(("id", num(id as f64)));
    }
    obj(fields).to_string_compact()
}

#[derive(PartialEq)]
enum Ingest {
    Continue,
    Shutdown,
}

/// Handle one queued line: control requests (shutdown/stats) inline,
/// workload requests into the scheduler, failures straight back.
fn ingest(
    job: Job,
    meta: &ModelMeta,
    grammar: &StoryGrammar,
    builder: &mut RequestBuilder,
    sched: &mut Scheduler<JobTag>,
) -> Ingest {
    if job.line.trim() == "shutdown" {
        let _ = job.reply.send("{\"ok\":true,\"shutdown\":true}".into());
        return Ingest::Shutdown;
    }
    let parsed = match Json::parse(&job.line) {
        Ok(j) => j,
        Err(e) => {
            let _ = job.reply.send(error_reply(None, &format!("bad json: {}", e)));
            return Ingest::Continue;
        }
    };
    let id = parsed.get("id").and_then(|v| v.as_i64());
    match parsed.get("kind").and_then(|v| v.as_str()) {
        Some("stats") => {
            let reply = if parsed.get("format").and_then(|v| v.as_str())
                == Some("prometheus")
            {
                // the exposition text travels as one JSON string field so
                // the line protocol stays one-object-per-line
                obj(vec![
                    ("kind", s("stats")),
                    ("format", s("prometheus")),
                    ("body", s(&sched.stats_prometheus())),
                ])
                .to_string_compact()
            } else {
                sched.stats_json().to_string_compact()
            };
            let _ = job.reply.send(reply);
            return Ingest::Continue;
        }
        Some("trace") => {
            let rid = parsed.get("id").and_then(|v| v.as_i64()).map(|i| i as u64);
            let last = parsed.get("last").and_then(|v| v.as_usize());
            let _ = job.reply.send(sched.trace_json(rid, last).to_string_compact());
            return Ingest::Continue;
        }
        Some("profile") => {
            let _ = job.reply.send(sched.profile_json().to_string_compact());
            return Ingest::Continue;
        }
        _ => {}
    }
    match synthesize(&parsed, meta, grammar, builder) {
        Ok((id, req)) => {
            let tag = JobTag { id, reply: job.reply };
            if let Err((tag, reason)) = sched.submit(tag, req) {
                let _ = tag.reply.send(error_reply(Some(tag.id), reason.message()));
            }
        }
        Err(e) => {
            let _ = job.reply.send(error_reply(id, &e.to_string()));
        }
    }
    Ingest::Continue
}

fn deliver(outcome: SchedOutcome<JobTag>) {
    match outcome {
        SchedOutcome::Done { tag, ar } => {
            let _ = tag.reply.send(respond(tag.id, &ar));
        }
        SchedOutcome::Failed { tag, error } => {
            let _ = tag.reply.send(error_reply(Some(tag.id), &error));
        }
    }
}

/// Run the server until `shutdown` (a line "shutdown" on any connection).
/// Blocks the calling thread with the router loop; the engine's
/// scheduler loop runs on its own replica thread. Binds `cfg.addr`
/// (port 0 picks a free port); callers that need the chosen port bind
/// their own listener and call [`serve_on`] / [`serve_replicas_on`]
/// directly (`harness::spawn_server` does — a fixed test port is a
/// collision flake waiting for parallel CI binaries).
pub fn serve(engine: Engine, cfg: ServerConfig, grammar: StoryGrammar) -> Result<()> {
    serve_replicas(vec![engine], cfg, grammar)
}

/// [`serve`] over N engine replicas behind one listener — the in-process
/// half of prefix-affinity sharded serving (ROADMAP item 2). Engines are
/// constructed by the caller (`--replicas N` builds N from one artifact
/// dir); each owns its own `PagePool`, prefix cache and device thread,
/// and runs its own scheduler loop on its own thread behind the router.
pub fn serve_replicas(
    engines: Vec<Engine>,
    cfg: ServerConfig,
    grammar: StoryGrammar,
) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    serve_replicas_on(engines, listener, cfg, grammar)
}

/// [`serve`] on an already-bound listener (the engine is constructed by
/// the caller's thread because the PJRT client is not Send, but a
/// listener is — so tests bind port 0, read the port back, and hand the
/// listener in).
pub fn serve_on(
    engine: Engine,
    listener: TcpListener,
    cfg: ServerConfig,
    grammar: StoryGrammar,
) -> Result<()> {
    serve_replicas_on(vec![engine], listener, cfg, grammar)
}

/// [`serve_replicas`] on an already-bound listener. The calling thread
/// runs the router loop; each replica's scheduler loop runs on a
/// `hae-replica-<i>` thread over its own ingest mailbox. Shutdown is a
/// full drain: the router broadcasts the shutdown line to every replica,
/// the acceptor joins its connection threads, and this function joins
/// the acceptor AND every replica thread — so when it returns, no server
/// thread is left running and every device thread has been joined by its
/// engine's drop at replica exit.
pub fn serve_replicas_on(
    engines: Vec<Engine>,
    listener: TcpListener,
    cfg: ServerConfig,
    grammar: StoryGrammar,
) -> Result<()> {
    if engines.is_empty() {
        bail!("serve_replicas_on needs at least one engine");
    }
    let local_addr = listener.local_addr()?;
    eprintln!("hae-serve listening on {} ({} replicas)", local_addr, engines.len());
    // mailbox between connection threads and the router; each replica's
    // scheduler admission queue is the real (rejecting) queue, so this
    // only needs enough slack that router classification stays cheap
    let (tx, rx) = mpsc::sync_channel::<Job>(cfg.queue_depth.max(1) * 4);
    let shutdown = Arc::new(AtomicBool::new(false));

    // acceptor thread — unblocked at shutdown by a self-connection from
    // the router loop (listener.incoming() cannot time out). It keeps
    // every connection thread's handle and joins them on exit, so joining
    // the acceptor proves the whole listener side has terminated.
    let acceptor = {
        let tx = tx.clone();
        let shutdown = shutdown.clone();
        let listener = listener.try_clone()?;
        std::thread::Builder::new()
            .name("hae-acceptor".into())
            .spawn(move || {
                let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
                for stream in listener.incoming().flatten() {
                    if shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let tx = tx.clone();
                    let shutdown = shutdown.clone();
                    conns.push(std::thread::spawn(move || {
                        let _ = handle_conn(stream, tx, shutdown);
                    }));
                }
                // readers poll the flag at read-timeout granularity, so
                // each join resolves within ~one CONN_READ_TIMEOUT
                for c in conns {
                    let _ = c.join();
                }
            })?
    };

    let meta = engines[0].meta().clone();
    let grammar = Arc::new(grammar);
    let mut links: Vec<ReplicaLink> = Vec::new();
    let mut replicas: Vec<std::thread::JoinHandle<Result<()>>> = Vec::new();
    for (i, engine) in engines.into_iter().enumerate() {
        // per-replica ingest mailbox, sized like the shared one so a
        // burst at one replica backpressures (or sheds) at the same
        // depth the single-engine server always has
        let (rtx, rrx) = mpsc::sync_channel::<Job>(cfg.queue_depth.max(1) * 4);
        let health = Arc::new(ReplicaHealth::new());
        links.push(ReplicaLink { tx: rtx, health: health.clone() });
        let rcfg = ReplicaCfg {
            queue_depth: cfg.queue_depth,
            kv_budget: cfg.kv_budget,
            sched_policy: cfg.sched_policy,
            engine_threads: cfg.engine_threads,
            slo: cfg.slo.clone(),
        };
        let grammar = grammar.clone();
        let main_tx = tx.clone();
        replicas.push(
            std::thread::Builder::new()
                .name(format!("hae-replica-{}", i))
                .spawn(move || replica_loop(engine, rrx, grammar, rcfg, health, main_tx))?,
        );
    }

    // router loop on this thread until a shutdown line (or a replica's
    // fatal error, surfaced as a synthetic shutdown). It consumes and
    // drops rx, so connection threads blocked in a full mailbox send
    // error out instead of deadlocking the acceptor join below.
    let router_cfg = RouterConfig {
        policy: cfg.router_policy,
        shed_queue: cfg.shed_queue,
        spill_occupancy: cfg.spill_occupancy,
    };
    router_loop(rx, &meta, &grammar, &links, &router_cfg);
    // dropping the links closes every replica mailbox: a replica that
    // somehow missed the shutdown broadcast still exits on disconnect
    drop(links);

    // prompt shutdown: flag first, then self-connect to pop the acceptor
    // out of listener.incoming()
    shutdown.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(local_addr);
    let _ = acceptor.join();
    let mut fatal: Option<anyhow::Error> = None;
    for r in replicas {
        match r.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => fatal = fatal.or(Some(e)),
            Err(_) => {
                fatal = fatal.or_else(|| Some(anyhow!("replica scheduler thread panicked")))
            }
        }
    }
    match fatal {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Per-replica slice of [`ServerConfig`]: what one scheduler thread
/// needs.
struct ReplicaCfg {
    queue_depth: usize,
    kv_budget: Option<usize>,
    sched_policy: SchedPolicy,
    engine_threads: usize,
    slo: SloTable,
}

/// One replica's scheduler loop — the single-engine serve loop, fed by
/// the replica's own ingest mailbox instead of the listener's. Device
/// calls run on this engine's dedicated device thread. The loop
/// publishes health once per round (lock-free atomics; the router reads
/// them for shed/spill/least-loaded placement).
///
/// A fatal engine error drains all in-flight work with error replies and
/// then injects a synthetic shutdown line into the shared mailbox, so
/// the router winds the WHOLE server down — a dead replica must not
/// leave the survivors serving a listener whose operator believes the
/// deployment is healthy (the pre-router server died whole; N replicas
/// keep that contract).
fn replica_loop(
    mut engine: Engine,
    rx: mpsc::Receiver<Job>,
    grammar: Arc<StoryGrammar>,
    cfg: ReplicaCfg,
    health: Arc<ReplicaHealth>,
    main_tx: mpsc::SyncSender<Job>,
) -> Result<()> {
    let meta = engine.meta().clone();
    let mut builder = RequestBuilder::new(&meta, &grammar, 0xBEEF);
    let mut fatal: Option<anyhow::Error> = engine.warmup().err();
    let sched_cfg = SchedulerConfig {
        kv_budget: cfg.kv_budget.unwrap_or_else(|| engine.kv_budget_ceiling()),
        policy: cfg.sched_policy,
        queue_depth: cfg.queue_depth,
        slo: cfg.slo.clone(),
        ..SchedulerConfig::default()
    };
    let mut sched: Scheduler<JobTag> = Scheduler::for_engine(sched_cfg, &engine);
    let pipelined = cfg.engine_threads > 1;

    if fatal.is_none() {
        'serve: loop {
            // ingest: block only when idle, otherwise drain
            // opportunistically between decode steps so new requests
            // join the batch mid-flight
            if !sched.has_work() {
                match rx.recv() {
                    Ok(job) => {
                        health.dequeue();
                        if ingest(job, &meta, &grammar, &mut builder, &mut sched)
                            == Ingest::Shutdown
                        {
                            break 'serve;
                        }
                    }
                    Err(_) => break 'serve,
                }
            }
            let mut stop =
                drain_ingest(&rx, &meta, &grammar, &mut builder, &mut sched, &health);
            publish_health(&health, &sched, &engine);
            if stop {
                break 'serve;
            }
            // one scheduling round: backfill free lanes, decode, retire. A
            // decode error is runtime-fatal (the whole batched step failed),
            // but outcomes are delivered first and cleanup still runs below,
            // so every in-flight client hears why instead of an abrupt EOF
            let tick_result = if pipelined {
                // pipelined round: submit the decode batch, then spend the
                // device window on host work — delivering finished replies,
                // draining new ingest, and backfilling freed lanes — before
                // blocking on the device reply in finish_step
                match sched.begin_step(&mut engine) {
                    Err(e) => Err(e),
                    Ok(pending) => {
                        if pending.is_some() {
                            // the profiled overlap window: all host work done
                            // while the submitted step computes on the device
                            let t0 = sched.obs.enabled().then(std::time::Instant::now);
                            for outcome in sched.take_outcomes() {
                                deliver(outcome);
                            }
                            stop = drain_ingest(
                                &rx, &meta, &grammar, &mut builder, &mut sched, &health,
                            );
                            sched.overlap_backfill(&mut engine);
                            if let Some(t0) = t0 {
                                sched.obs.record(|o| {
                                    o.profile
                                        .step_overlap_ms
                                        .record(t0.elapsed().as_secs_f64() * 1e3);
                                });
                            }
                        }
                        // a shutdown seen mid-flight still collects the step:
                        // the in-flight lanes finish and reply before we drain
                        sched.finish_step(&mut engine, pending)
                    }
                }
            } else {
                sched.tick(&mut engine)
            };
            for outcome in sched.take_outcomes() {
                deliver(outcome);
            }
            if let Err(e) = tick_result {
                fatal = Some(e);
                break 'serve;
            }
            if stop {
                break 'serve;
            }
        }
    }

    // drain: in-flight work answers, queued work hears why
    for outcome in sched.take_outcomes() {
        deliver(outcome);
    }
    let reason = match &fatal {
        Some(e) => format!("engine error: {}", e),
        None => "server shutting down".to_string(),
    };
    for tag in sched.drain_tags() {
        let _ = tag.reply.send(error_reply(Some(tag.id), &reason));
    }
    // disconnect our mailbox BEFORE the synthetic shutdown below: the
    // router may be blocked sending into it, and that send must error
    // out rather than deadlock against our own send into the shared
    // mailbox it is no longer draining
    drop(rx);
    if let Some(e) = fatal {
        let (dtx, _drx) = mpsc::channel::<String>();
        let _ = main_tx.send(Job { line: "shutdown".into(), reply: dtx });
        return Err(e);
    }
    Ok(())
    // `engine` drops here, joining the device thread (DeviceHandle drop
    // closes the request channel first, so the join cannot hang)
}

/// Publish one round's scheduler/pool snapshot for the router. The pool
/// lock is taken and released inside `pool_stats` — never held across
/// anything (docs/CONCURRENCY.md lock order).
fn publish_health(health: &ReplicaHealth, sched: &Scheduler<JobTag>, engine: &Engine) {
    let pool = engine.pool_stats();
    health.publish(
        sched.queue_len(),
        sched.lanes_occupied(),
        pool.in_use,
        pool.pages,
        sched.metrics.slo_attainment(),
    );
}

/// Pull every queued job off the replica's ingest mailbox without
/// blocking. Returns `true` when a shutdown line was seen (the caller
/// breaks its serve loop after finishing any in-flight step).
fn drain_ingest(
    rx: &mpsc::Receiver<Job>,
    meta: &ModelMeta,
    grammar: &StoryGrammar,
    builder: &mut RequestBuilder,
    sched: &mut Scheduler<JobTag>,
    health: &ReplicaHealth,
) -> bool {
    loop {
        match rx.try_recv() {
            Ok(job) => {
                health.dequeue();
                if ingest(job, meta, grammar, builder, sched) == Ingest::Shutdown {
                    return true;
                }
            }
            Err(mpsc::TryRecvError::Empty) | Err(mpsc::TryRecvError::Disconnected) => {
                return false;
            }
        }
    }
}

/// How often an idle connection reader re-checks the shutdown flag.
/// Bounds how long a parked reader thread can outlive `serve_on`.
const CONN_READ_TIMEOUT: Duration = Duration::from_millis(50);

fn handle_conn(
    stream: TcpStream,
    tx: mpsc::SyncSender<Job>,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    let writer_stream = stream.try_clone()?;
    let (rtx, rrx) = mpsc::channel::<String>();
    // writer thread: replies land whenever the scheduler finishes each
    // request — possibly out of request order; ids disambiguate
    let writer = std::thread::spawn(move || {
        let mut w = writer_stream;
        for resp in rrx {
            if w
                .write_all(resp.as_bytes())
                .and_then(|_| w.write_all(b"\n"))
                .is_err()
            {
                break;
            }
        }
    });
    // finite read timeout so a client that connects and goes quiet cannot
    // pin this thread past shutdown; a timeout with a partial line in
    // `buf` keeps accumulating — read_line appends, it never discards
    stream.set_read_timeout(Some(CONN_READ_TIMEOUT))?;
    let mut reader = BufReader::new(stream);
    let mut buf = String::new();
    loop {
        match reader.read_line(&mut buf) {
            Ok(0) => break, // EOF (any unterminated partial line is dropped)
            Ok(_) => {
                let line = std::mem::take(&mut buf);
                let line = line.trim_end_matches(['\r', '\n']).to_string();
                if !line.trim().is_empty()
                    && tx.send(Job { line, reply: rtx.clone() }).is_err()
                {
                    break;
                }
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    drop(rtx);
    let _ = writer.join();
    Ok(())
}

/// Blocking one-shot client used by examples and tests.
pub fn client_request(addr: &str, payload: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(payload.as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(line.trim().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelMeta;

    fn meta() -> ModelMeta {
        ModelMeta {
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_head: 32,
            d_mlp: 256,
            patch_dim: 32,
            n_patches: 16,
            max_pos: 640,
            dap_layer: 1,
        }
    }

    fn parse(line: &str) -> Json {
        Json::parse(line).unwrap()
    }

    #[test]
    fn synthesize_parses_kinds() {
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b = RequestBuilder::new(&m, &g, 5);
        let (id, req) =
            synthesize(&parse(r#"{"id": 7, "kind": "qa"}"#), &m, &g, &mut b).unwrap();
        assert_eq!(id, 7);
        assert_eq!(req.kind, WorkloadKind::Understanding);
        let (_, req) = synthesize(
            &parse(r#"{"id": 1, "kind": "story", "max_new": 12}"#),
            &m,
            &g,
            &mut b,
        )
        .unwrap();
        assert_eq!(req.max_new_tokens, 12);
        assert!(synthesize(&parse(r#"{"kind": "nope"}"#), &m, &g, &mut b).is_err());
        // malformed lines never reach synthesize: ingest rejects them
        assert!(Json::parse("not json").is_err());
    }

    #[test]
    fn kind_errors_list_accepted_values() {
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b = RequestBuilder::new(&m, &g, 5);
        let err = synthesize(&parse(r#"{"id": 3, "kind": "nope"}"#), &m, &g, &mut b)
            .unwrap_err()
            .to_string();
        assert!(err.contains("nope"), "names the bad value: {}", err);
        assert!(err.contains("story") && err.contains("qa"), "lists accepted: {}", err);
        let err = synthesize(&parse(r#"{"id": 3}"#), &m, &g, &mut b)
            .unwrap_err()
            .to_string();
        assert!(err.contains("accepted"), "missing kind lists accepted: {}", err);
        // the error reply the scheduler path sends echoes the id with it
        let reply = error_reply(Some(3), &err);
        let j = Json::parse(&reply).unwrap();
        assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(3));
        assert!(j.get("error").and_then(|v| v.as_str()).unwrap().contains("accepted"));
    }

    #[test]
    fn image_seed_makes_shared_visual_prefixes() {
        let m = meta();
        let g = StoryGrammar::uniform();
        // two different connection-shared builders: same image_seed →
        // identical visual prefix, question selected by "q"
        let mut b1 = RequestBuilder::new(&m, &g, 5);
        let mut b2 = RequestBuilder::new(&m, &g, 999);
        let color = parse(r#"{"id": 1, "kind": "qa", "image_seed": 7, "q": "color"}"#);
        let shape = parse(r#"{"id": 2, "kind": "qa", "image_seed": 7, "q": "shape"}"#);
        let (_, r1) = synthesize(&color, &m, &g, &mut b1).unwrap();
        let (_, r2) = synthesize(&color, &m, &g, &mut b2).unwrap();
        assert_eq!(r1.ids, r2.ids);
        assert_eq!(r1.patches, r2.patches);
        let (_, r3) = synthesize(&shape, &m, &g, &mut b1).unwrap();
        let pre = 1 + m.n_patches;
        assert_eq!(&r3.patches[..pre * m.patch_dim], &r1.patches[..pre * m.patch_dim]);
        assert_ne!(r3.ids, r1.ids, "different question token");
        // unknown q is rejected with the accepted values
        let bad = parse(r#"{"id": 1, "kind": "qa", "image_seed": 7, "q": "size"}"#);
        let err = synthesize(&bad, &m, &g, &mut b1).unwrap_err().to_string();
        assert!(err.contains("size") && err.contains("color"), "{}", err);
    }

    #[test]
    fn dialog_turns_synthesize_distinct_prompts_over_one_image() {
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b1 = RequestBuilder::new(&m, &g, 5);
        let mut b2 = RequestBuilder::new(&m, &g, 999);
        let t0 = parse(r#"{"id": 1, "kind": "qa", "image_seed": 7, "turn": 0}"#);
        let t2 = parse(r#"{"id": 2, "kind": "qa", "image_seed": 7, "turn": 2}"#);
        let (_, r0) = synthesize(&t0, &m, &g, &mut b1).unwrap();
        let (_, r2) = synthesize(&t2, &m, &g, &mut b1).unwrap();
        let pre = 1 + m.n_patches;
        assert_ne!(r0.ids, r2.ids, "turns are distinct prompts");
        assert_eq!(&r2.patches[..pre * m.patch_dim], &r0.patches[..pre * m.patch_dim]);
        assert!(r2.prompt_len() > r0.prompt_len(), "history grows the prompt");
        // reproducible across connections
        let (_, again) = synthesize(&t2, &m, &g, &mut b2).unwrap();
        assert_eq!(again.ids, r2.ids);
        // negative turns are rejected
        let bad = parse(r#"{"id": 1, "kind": "qa", "image_seed": 7, "turn": -1}"#);
        assert!(synthesize(&bad, &m, &g, &mut b1).is_err());
    }

    #[test]
    fn seed_makes_requests_reproducible() {
        let m = meta();
        let g = StoryGrammar::uniform();
        // two different connection-shared builders, same seeded line
        let mut b1 = RequestBuilder::new(&m, &g, 5);
        let mut b2 = RequestBuilder::new(&m, &g, 999);
        let line = parse(r#"{"id": 1, "kind": "story", "seed": 42}"#);
        let (_, r1) = synthesize(&line, &m, &g, &mut b1).unwrap();
        let (_, r2) = synthesize(&line, &m, &g, &mut b2).unwrap();
        assert_eq!(r1.ids, r2.ids);
        assert_eq!(r1.patches, r2.patches);
        // unseeded requests keep drawing from the shared stream
        let unseeded = parse(r#"{"id": 2, "kind": "story"}"#);
        let (_, u1) = synthesize(&unseeded, &m, &g, &mut b1).unwrap();
        let (_, u2) = synthesize(&unseeded, &m, &g, &mut b2).unwrap();
        assert_ne!(u1.ids, u2.ids);
    }

    fn test_sched() -> Scheduler<JobTag> {
        // runtime-free: geometry matching the scheduler's own unit tests
        Scheduler::new(SchedulerConfig::default(), 4, 64, 100, 1, 1024)
    }

    fn ingest_line(line: &str, sched: &mut Scheduler<JobTag>) -> String {
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b = RequestBuilder::new(&m, &g, 5);
        let (rtx, rrx) = mpsc::channel::<String>();
        let out = ingest(Job { line: line.into(), reply: rtx }, &m, &g, &mut b, sched);
        assert!(out == Ingest::Continue);
        rrx.recv().expect("control requests reply inline")
    }

    #[test]
    fn stats_reply_keeps_flat_keys_and_adds_phases() {
        let mut sc = test_sched();
        let j = Json::parse(&ingest_line(r#"{"kind": "stats"}"#, &mut sc)).unwrap();
        for key in ["kind", "queue_depth", "submitted", "ttft_p50_ms", "e2e_p95_ms"] {
            assert!(j.get(key).is_some(), "missing {}", key);
        }
        assert!(j.path(&["phases", "prefill_ms", "count"]).is_some());
        // serving-profiler additions ride along: device health, overall
        // SLO attainment, and the nested per-class block
        for key in ["device_busy_us", "device_queue_depth", "slo_attainment"] {
            assert!(j.get(key).is_some(), "missing {}", key);
        }
        for class in ["qa", "story", "video", "mixed"] {
            assert!(
                j.path(&["classes", class, "ttft_p50_ms"]).is_some(),
                "missing class {}",
                class
            );
        }
    }

    #[test]
    fn prometheus_stats_reply_wraps_valid_exposition() {
        let mut sc = test_sched();
        let line = r#"{"kind": "stats", "format": "prometheus"}"#;
        let j = Json::parse(&ingest_line(line, &mut sc)).unwrap();
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("stats"));
        assert_eq!(j.get("format").and_then(|v| v.as_str()), Some("prometheus"));
        let body = j.get("body").and_then(|v| v.as_str()).unwrap();
        assert!(crate::obs::prometheus::parses_as_exposition(body), "{}", body);
        assert!(body.contains("hae_requests_submitted_total"));
        assert!(body.contains("hae_ttft_ms_bucket"));
        // device-thread health and the profiler spans are wired into the
        // same exposition body (docs/OBSERVABILITY.md series table)
        assert!(body.contains("hae_device_busy_us_total"));
        assert!(body.contains("hae_device_queue_depth"));
        assert!(body.contains("hae_pool_lock_wait_ms"));
        assert!(body.contains("hae_class_ttft_p95_ms{class=\"video\"}"));
        assert!(body.contains("hae_slo_attainment"));
    }

    #[test]
    fn profile_reply_carries_spans_and_device_totals() {
        let mut sc = test_sched();
        let j = Json::parse(&ingest_line(r#"{"kind": "profile"}"#, &mut sc)).unwrap();
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("profile"));
        for span in [
            "pool_lock_wait_ms",
            "device_send_wait_ms",
            "step_begin_ms",
            "step_overlap_ms",
            "step_finish_ms",
            "device_queue_depth",
        ] {
            assert!(j.path(&["spans", span, "p95"]).is_some(), "missing span {}", span);
        }
        for key in ["busy_us", "send_wait_us", "calls", "queue_depth", "peak_queue_depth"] {
            assert!(j.path(&["device", key]).is_some(), "missing device key {}", key);
        }
    }

    #[test]
    fn trace_reply_carries_lifecycle_events() {
        let mut sc = test_sched();
        // queue a request through the real ingest path (never admitted —
        // no engine runs in this test — so only Enqueued is journaled)
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b = RequestBuilder::new(&m, &g, 5);
        let (rtx, _rrx) = mpsc::channel::<String>();
        let line = r#"{"id": 42, "kind": "qa", "max_new": 4}"#.to_string();
        assert!(ingest(Job { line, reply: rtx }, &m, &g, &mut b, &mut sc) == Ingest::Continue);

        let j = Json::parse(&ingest_line(r#"{"kind": "trace", "id": 42}"#, &mut sc)).unwrap();
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("trace"));
        assert_eq!(j.get("count").and_then(|v| v.as_i64()), Some(1));
        let ev = j.get("events").unwrap().as_arr().unwrap();
        assert_eq!(ev[0].get("id").and_then(|v| v.as_i64()), Some(42));
        assert_eq!(ev[0].get("event").and_then(|v| v.as_str()), Some("enqueued"));
        // journal-wide query sees it too
        let j = Json::parse(&ingest_line(r#"{"kind": "trace", "last": 8}"#, &mut sc)).unwrap();
        assert_eq!(j.get("count").and_then(|v| v.as_i64()), Some(1));
    }

    #[test]
    fn respond_includes_phase_timing_fields() {
        // a respond() line must carry the new per-request phase fields
        // with warm-hit semantics (prefill_ms 0, extend_* populated)
        use crate::cache::baselines::FullCache;
        use crate::cache::KvSlab;
        use crate::coordinator::ActiveRequest;
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b = RequestBuilder::new(&m, &g, 5);
        let (_, req) = synthesize(
            &parse(r#"{"id": 9, "kind": "qa", "max_new": 4}"#),
            &m,
            &g,
            &mut b,
        )
        .unwrap();
        let mut ar = ActiveRequest {
            req,
            slab: KvSlab::new(&m, 64),
            policy: Box::new(FullCache),
            generated: vec![3, 4],
            pos: 2,
            prefill_len: 2,
            pending_token: 4,
            done: true,
            forced: None,
            logits_trace: Vec::new(),
            score_trace: Vec::new(),
            evictions: Vec::new(),
            stats: Default::default(),
        };
        ar.stats.queue_s = 0.002;
        ar.stats.extend_s = 0.005;
        ar.stats.extend_calls = 2;
        let j = Json::parse(&respond(9, &ar)).unwrap();
        assert_eq!(j.get("prefill_ms").and_then(|v| v.as_f64()), Some(0.0));
        assert!((j.get("queue_ms").and_then(|v| v.as_f64()).unwrap() - 2.0).abs() < 1e-9);
        assert!((j.get("extend_ms").and_then(|v| v.as_f64()).unwrap() - 5.0).abs() < 1e-9);
        assert_eq!(j.get("extend_calls").and_then(|v| v.as_i64()), Some(2));
    }

    #[test]
    fn error_reply_escapes_and_echoes_id() {
        let r = error_reply(Some(9), "bad \"quoted\"\nthing");
        let j = Json::parse(&r).unwrap();
        assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(9));
        assert_eq!(
            j.get("error").and_then(|v| v.as_str()),
            Some("bad \"quoted\"\nthing")
        );
        // id omitted when unknown
        let j = Json::parse(&error_reply(None, "x")).unwrap();
        assert!(j.get("id").is_none());
    }
}
