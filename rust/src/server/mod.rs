//! JSON-lines TCP front end.
//!
//! Protocol: one JSON object per line.
//!   request:  {"id": 1, "kind": "story"|"qa"|"video"|"mixed",
//!              "max_new": 64, "seed": 123}
//!            (requests are synthesized server-side from the workload
//!             generators — the "tokenizer + vision encoder" of this
//!             system; an external-prompt variant would marshal patches,
//!             which the JSON substrate supports but the demo doesn't need)
//!   response: {"id": 1, "tokens": [...], "text": "...",
//!              "prefill_ms": ..., "decode_ms": ..., "steps": N,
//!              "pruned": N, "evicted": N, "peak_kv_kib": N}
//!
//! Architecture: acceptor threads feed a bounded channel into the single
//! engine thread (the PJRT client is single-threaded by design); responses
//! flow back through per-connection channels. This is the leader/worker
//! split of DESIGN.md §2 at CPU scale.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::Engine;
use crate::model::vocab;
use crate::util::json::{num, obj, s, Json};
use crate::workload::{RequestBuilder, StoryGrammar, WorkloadKind};

pub struct ServerConfig {
    pub addr: String,
    /// max queued requests before backpressure (connection blocks)
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:8472".into(), queue_depth: 64 }
    }
}

struct Job {
    line: String,
    reply: mpsc::Sender<String>,
}

/// Parse one request line into a workload Request (synthesized).
fn synthesize(
    line: &str,
    builder: &mut RequestBuilder,
) -> Result<(i64, crate::workload::Request)> {
    let j = Json::parse(line).map_err(|e| anyhow!("bad json: {}", e))?;
    let id = j.get("id").and_then(|v| v.as_i64()).unwrap_or(0);
    let kind = j
        .get("kind")
        .and_then(|v| v.as_str())
        .and_then(WorkloadKind::parse)
        .ok_or_else(|| anyhow!("missing/unknown kind"))?;
    let mut req = builder.make(kind);
    if let Some(mx) = j.get("max_new").and_then(|v| v.as_usize()) {
        req.max_new_tokens = mx;
        req.min_new_tokens = req.min_new_tokens.min(mx);
    }
    Ok((id, req))
}

fn respond(id: i64, ar: &crate::coordinator::ActiveRequest) -> String {
    let text: Vec<String> = ar.generated.iter().map(|&t| vocab::describe(t)).collect();
    obj(vec![
        ("id", num(id as f64)),
        (
            "tokens",
            Json::Arr(ar.generated.iter().map(|&t| num(t as f64)).collect()),
        ),
        ("text", s(&text.join(" "))),
        ("prefill_ms", num(ar.stats.prefill_s * 1000.0)),
        ("decode_ms", num(ar.stats.decode_s * 1000.0)),
        ("steps", num(ar.stats.steps as f64)),
        ("pruned", num(ar.stats.pruned_at_prefill as f64)),
        ("evicted", num(ar.stats.evicted_at_decode as f64)),
        ("peak_kv_kib", num(ar.stats.peak_kv_bytes as f64 / 1024.0)),
    ])
    .to_string_compact()
}

/// Run the server until `shutdown` (a line "shutdown" on any connection).
/// Blocks the calling thread with the engine loop.
pub fn serve(mut engine: Engine, cfg: ServerConfig, grammar: StoryGrammar) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    eprintln!("hae-serve listening on {}", cfg.addr);
    let (tx, rx) = mpsc::sync_channel::<Job>(cfg.queue_depth);
    let shutdown = Arc::new(Mutex::new(false));

    // acceptor thread
    {
        let tx = tx.clone();
        let shutdown = shutdown.clone();
        let listener = listener.try_clone()?;
        std::thread::spawn(move || {
            for stream in listener.incoming().flatten() {
                if *shutdown.lock().unwrap() {
                    break;
                }
                let tx = tx.clone();
                let shutdown = shutdown.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, tx, shutdown);
                });
            }
        });
    }

    // engine loop (single-threaded PJRT owner)
    let meta = engine.rt.meta().clone();
    let mut builder = RequestBuilder::new(&meta, &grammar, 0xBEEF);
    engine.rt.warmup(&[engine.cfg.batch])?;
    loop {
        let job = match rx.recv() {
            Ok(j) => j,
            Err(_) => break,
        };
        if job.line.trim() == "shutdown" {
            *shutdown.lock().unwrap() = true;
            let _ = job.reply.send("{\"ok\":true,\"shutdown\":true}".into());
            break;
        }
        let reply = match synthesize(&job.line, &mut builder) {
            Ok((id, req)) => match engine.generate(req) {
                Ok(ar) => respond(id, &ar),
                Err(e) => format!("{{\"error\":\"{}\"}}", e),
            },
            Err(e) => format!("{{\"error\":\"{}\"}}", e),
        };
        let _ = job.reply.send(reply);
    }
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    tx: mpsc::SyncSender<Job>,
    shutdown: Arc<Mutex<bool>>,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (rtx, rrx) = mpsc::channel();
        if tx.send(Job { line, reply: rtx }).is_err() {
            break;
        }
        match rrx.recv() {
            Ok(resp) => {
                writer.write_all(resp.as_bytes())?;
                writer.write_all(b"\n")?;
            }
            Err(_) => break,
        }
        if *shutdown.lock().unwrap() {
            break;
        }
    }
    Ok(())
}

/// Blocking one-shot client used by examples and tests.
pub fn client_request(addr: &str, payload: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(payload.as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(line.trim().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelMeta;

    fn meta() -> ModelMeta {
        ModelMeta {
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_head: 32,
            d_mlp: 256,
            patch_dim: 32,
            n_patches: 16,
            max_pos: 640,
            dap_layer: 1,
        }
    }

    #[test]
    fn synthesize_parses_kinds() {
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b = RequestBuilder::new(&m, &g, 5);
        let (id, req) =
            synthesize(r#"{"id": 7, "kind": "qa"}"#, &mut b).unwrap();
        assert_eq!(id, 7);
        assert_eq!(req.kind, WorkloadKind::Understanding);
        let (_, req) =
            synthesize(r#"{"id": 1, "kind": "story", "max_new": 12}"#, &mut b).unwrap();
        assert_eq!(req.max_new_tokens, 12);
        assert!(synthesize(r#"{"kind": "nope"}"#, &mut b).is_err());
        assert!(synthesize("not json", &mut b).is_err());
    }
}
