//! JSON-lines TCP front end.
//!
//! Protocol: one JSON object per line.
//!   request:  {"id": 1, "kind": "story"|"qa"|"video"|"mixed",
//!              "max_new": 64, "seed": 123}
//!            (requests are synthesized server-side from the workload
//!             generators — the "tokenizer + vision encoder" of this
//!             system; a "seed" field makes the synthesized prompt
//!             reproducible across connections)
//!   shared-image QA: {"id": 1, "kind": "qa", "image_seed": 7,
//!                     "q": "color"|"shape"}
//!            (the image is drawn from "image_seed" alone, so every
//!             request naming the same image carries a bit-identical
//!             visual prefix — the engine's radix-tree prefix cache
//!             serves repeat questions without recomputing prefill)
//!   multi-turn dialog: {"id": 1, "kind": "qa", "image_seed": 7,
//!                       "turn": 3}
//!            (turn T's prompt replays turns 0..T's Q/A history and ends
//!             at a fresh question — every turn is a distinct prompt, so
//!             exact reuse is impossible; the engine serves them through
//!             partial-prefix warm starts: the image's cached KV is
//!             adopted copy-on-write and only the dialog text is
//!             recomputed, with the pruning decision re-run per request)
//!   stats:    {"kind": "stats"} → scheduler metrics snapshot
//!             (queue depth, TTFT/e2e percentiles, lanes histogram,
//!              admission rejections, aggregate KV bytes)
//!   response: {"id": 1, "tokens": [...], "text": "...",
//!              "prefill_ms": ..., "decode_ms": ..., "steps": N,
//!              "pruned": N, "evicted": N, "peak_kv_kib": N}
//!   error:    {"id": 1, "error": "..."} (id echoed whenever the request
//!             line carried one)
//!
//! Architecture: acceptor + per-connection reader/writer threads feed a
//! channel into the single engine thread (the PJRT client is
//! single-threaded by design). The engine thread runs the
//! continuous-batching scheduler (scheduler::Scheduler): requests join
//! free decode lanes mid-flight under KV-budget admission control, and
//! each response flows back through its connection's channel the moment
//! that request finishes — short requests are never serialized behind
//! long generations admitted earlier.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::Engine;
use crate::model::{vocab, ModelMeta};
use crate::scheduler::{SchedOutcome, SchedPolicy, Scheduler, SchedulerConfig};
use crate::util::json::{num, obj, s, Json};
use crate::workload::{RequestBuilder, StoryGrammar, WorkloadKind};

pub struct ServerConfig {
    pub addr: String,
    /// max requests waiting for admission before graceful rejection
    pub queue_depth: usize,
    /// aggregate live-KV budget in bytes (None → engine ceiling)
    pub kv_budget: Option<usize>,
    pub sched_policy: SchedPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:8472".into(),
            queue_depth: 64,
            kv_budget: None,
            sched_policy: SchedPolicy::Fifo,
        }
    }
}

struct Job {
    line: String,
    reply: mpsc::Sender<String>,
}

/// Scheduler tag: everything needed to answer a request later.
struct JobTag {
    id: i64,
    reply: mpsc::Sender<String>,
}

/// Turn one parsed request object into a workload Request (synthesized).
/// A "seed" field draws the prompt from a fresh builder at that seed so
/// identical request lines produce identical prompts on any connection;
/// without it the connection-shared builder stream is used.
fn synthesize(
    j: &Json,
    meta: &ModelMeta,
    grammar: &StoryGrammar,
    builder: &mut RequestBuilder,
) -> Result<(i64, crate::workload::Request)> {
    let id = j.get("id").and_then(|v| v.as_i64()).unwrap_or(0);
    let kind_str = j
        .get("kind")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing kind (accepted: {})", WorkloadKind::accepted()))?;
    let kind = WorkloadKind::parse(kind_str).ok_or_else(|| {
        anyhow!("unknown kind '{}' (accepted: {})", kind_str, WorkloadKind::accepted())
    })?;
    let mut req = match (kind, j.get("image_seed").and_then(|v| v.as_i64())) {
        (WorkloadKind::Understanding, Some(iseed)) => {
            // shared-image QA: the image depends on image_seed alone, so
            // co-referencing requests share a bit-identical visual prefix.
            // "turn" selects a multi-turn dialog prompt (distinct per
            // turn — served via partial-prefix warm starts); "q" the
            // single-turn question
            if let Some(turn) = j.get("turn").and_then(|v| v.as_i64()) {
                if turn < 0 {
                    bail!("turn must be >= 0, got {}", turn);
                }
                builder.qa_dialog_turn(iseed as u64, turn as usize)
            } else {
                let ask_color = match j.get("q").and_then(|v| v.as_str()) {
                    None | Some("color") => true,
                    Some("shape") => false,
                    Some(other) => {
                        bail!("unknown q '{}' (accepted: color, shape)", other)
                    }
                };
                builder.understanding_shared(iseed as u64, ask_color)
            }
        }
        _ => match j.get("seed").and_then(|v| v.as_i64()) {
            Some(seed) => RequestBuilder::new(meta, grammar, seed as u64).make(kind),
            None => builder.make(kind),
        },
    };
    if let Some(mx) = j.get("max_new").and_then(|v| v.as_usize()) {
        req.max_new_tokens = mx;
        req.min_new_tokens = req.min_new_tokens.min(mx);
    }
    Ok((id, req))
}

fn respond(id: i64, ar: &crate::coordinator::ActiveRequest) -> String {
    let text: Vec<String> = ar.generated.iter().map(|&t| vocab::describe(t)).collect();
    obj(vec![
        ("id", num(id as f64)),
        (
            "tokens",
            Json::Arr(ar.generated.iter().map(|&t| num(t as f64)).collect()),
        ),
        ("text", s(&text.join(" "))),
        ("prefill_ms", num(ar.stats.prefill_s * 1000.0)),
        ("decode_ms", num(ar.stats.decode_s * 1000.0)),
        ("steps", num(ar.stats.steps as f64)),
        ("pruned", num(ar.stats.pruned_at_prefill as f64)),
        ("evicted", num(ar.stats.evicted_at_decode as f64)),
        ("peak_kv_kib", num(ar.stats.peak_kv_bytes as f64 / 1024.0)),
    ])
    .to_string_compact()
}

/// JSON error object, escaped through the serializer and echoing the
/// request id when one is known.
fn error_reply(id: Option<i64>, err: &str) -> String {
    let mut fields = vec![("error", s(err))];
    if let Some(id) = id {
        fields.push(("id", num(id as f64)));
    }
    obj(fields).to_string_compact()
}

#[derive(PartialEq)]
enum Ingest {
    Continue,
    Shutdown,
}

/// Handle one queued line: control requests (shutdown/stats) inline,
/// workload requests into the scheduler, failures straight back.
fn ingest(
    job: Job,
    meta: &ModelMeta,
    grammar: &StoryGrammar,
    builder: &mut RequestBuilder,
    sched: &mut Scheduler<JobTag>,
) -> Ingest {
    if job.line.trim() == "shutdown" {
        let _ = job.reply.send("{\"ok\":true,\"shutdown\":true}".into());
        return Ingest::Shutdown;
    }
    let parsed = match Json::parse(&job.line) {
        Ok(j) => j,
        Err(e) => {
            let _ = job.reply.send(error_reply(None, &format!("bad json: {}", e)));
            return Ingest::Continue;
        }
    };
    let id = parsed.get("id").and_then(|v| v.as_i64());
    if parsed.get("kind").and_then(|v| v.as_str()) == Some("stats") {
        let _ = job.reply.send(sched.stats_json().to_string_compact());
        return Ingest::Continue;
    }
    match synthesize(&parsed, meta, grammar, builder) {
        Ok((id, req)) => {
            let tag = JobTag { id, reply: job.reply };
            if let Err((tag, reason)) = sched.submit(tag, req) {
                let _ = tag.reply.send(error_reply(Some(tag.id), reason.message()));
            }
        }
        Err(e) => {
            let _ = job.reply.send(error_reply(id, &e.to_string()));
        }
    }
    Ingest::Continue
}

fn deliver(outcome: SchedOutcome<JobTag>) {
    match outcome {
        SchedOutcome::Done { tag, ar } => {
            let _ = tag.reply.send(respond(tag.id, &ar));
        }
        SchedOutcome::Failed { tag, error } => {
            let _ = tag.reply.send(error_reply(Some(tag.id), &error));
        }
    }
}

/// Run the server until `shutdown` (a line "shutdown" on any connection).
/// Blocks the calling thread with the engine/scheduler loop. Binds
/// `cfg.addr` (port 0 picks a free port) and delegates to [`serve_on`];
/// callers that need the chosen port bind their own listener and call
/// `serve_on` directly (`harness::spawn_server` does — a fixed test
/// port is a collision flake waiting for parallel CI binaries).
pub fn serve(engine: Engine, cfg: ServerConfig, grammar: StoryGrammar) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    serve_on(engine, listener, cfg, grammar)
}

/// [`serve`] on an already-bound listener (the engine is constructed by
/// the caller's thread because the PJRT client is not Send, but a
/// listener is — so tests bind port 0, read the port back, and hand the
/// listener in).
pub fn serve_on(
    mut engine: Engine,
    listener: TcpListener,
    cfg: ServerConfig,
    grammar: StoryGrammar,
) -> Result<()> {
    let local_addr = listener.local_addr()?;
    eprintln!("hae-serve listening on {}", local_addr);
    // mailbox between connection threads and the engine thread; the
    // scheduler's admission queue is the real (rejecting) queue, so this
    // only needs enough slack that ingest drains stay cheap
    let (tx, rx) = mpsc::sync_channel::<Job>(cfg.queue_depth.max(1) * 4);
    let shutdown = Arc::new(AtomicBool::new(false));

    // acceptor thread — unblocked at shutdown by a self-connection from
    // the engine loop (listener.incoming() cannot time out)
    {
        let tx = tx.clone();
        let shutdown = shutdown.clone();
        let listener = listener.try_clone()?;
        std::thread::spawn(move || {
            for stream in listener.incoming().flatten() {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let tx = tx.clone();
                let shutdown = shutdown.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, tx, shutdown);
                });
            }
        });
    }

    // engine thread (single-threaded PJRT owner) running the scheduler
    let meta = engine.rt.meta().clone();
    let mut builder = RequestBuilder::new(&meta, &grammar, 0xBEEF);
    engine.rt.warmup(&[engine.cfg.batch])?;
    let sched_cfg = SchedulerConfig {
        kv_budget: cfg.kv_budget.unwrap_or_else(|| engine.kv_budget_ceiling()),
        policy: cfg.sched_policy,
        queue_depth: cfg.queue_depth,
        ..SchedulerConfig::default()
    };
    let mut sched: Scheduler<JobTag> = Scheduler::for_engine(sched_cfg, &engine);
    let mut fatal: Option<anyhow::Error> = None;

    'serve: loop {
        // ingest: block only when idle, otherwise drain opportunistically
        // between decode steps so new requests join the batch mid-flight
        if !sched.has_work() {
            match rx.recv() {
                Ok(job) => {
                    if ingest(job, &meta, &grammar, &mut builder, &mut sched)
                        == Ingest::Shutdown
                    {
                        break 'serve;
                    }
                }
                Err(_) => break 'serve,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(job) => {
                    if ingest(job, &meta, &grammar, &mut builder, &mut sched)
                        == Ingest::Shutdown
                    {
                        break 'serve;
                    }
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => break,
            }
        }
        // one scheduling round: backfill free lanes, decode, retire. A
        // decode error is runtime-fatal (the whole batched step failed),
        // but outcomes are delivered first and cleanup still runs below,
        // so every in-flight client hears why instead of an abrupt EOF
        let tick_result = sched.tick(&mut engine);
        for outcome in sched.take_outcomes() {
            deliver(outcome);
        }
        if let Err(e) = tick_result {
            fatal = Some(e);
            break 'serve;
        }
    }

    // prompt shutdown: flag first, then self-connect to pop the acceptor
    // out of listener.incoming(); in-flight work gets an error reply
    shutdown.store(true, Ordering::SeqCst);
    for outcome in sched.take_outcomes() {
        deliver(outcome);
    }
    let reason = match &fatal {
        Some(e) => format!("engine error: {}", e),
        None => "server shutting down".to_string(),
    };
    for tag in sched.drain_tags() {
        let _ = tag.reply.send(error_reply(Some(tag.id), &reason));
    }
    let _ = TcpStream::connect(local_addr);
    match fatal {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

fn handle_conn(
    stream: TcpStream,
    tx: mpsc::SyncSender<Job>,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    let writer_stream = stream.try_clone()?;
    let (rtx, rrx) = mpsc::channel::<String>();
    // writer thread: replies land whenever the scheduler finishes each
    // request — possibly out of request order; ids disambiguate
    let writer = std::thread::spawn(move || {
        let mut w = writer_stream;
        for resp in rrx {
            if w
                .write_all(resp.as_bytes())
                .and_then(|_| w.write_all(b"\n"))
                .is_err()
            {
                break;
            }
        }
    });
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        if tx.send(Job { line, reply: rtx.clone() }).is_err() {
            break;
        }
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    drop(rtx);
    let _ = writer.join();
    Ok(())
}

/// Blocking one-shot client used by examples and tests.
pub fn client_request(addr: &str, payload: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(payload.as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(line.trim().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelMeta;

    fn meta() -> ModelMeta {
        ModelMeta {
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_head: 32,
            d_mlp: 256,
            patch_dim: 32,
            n_patches: 16,
            max_pos: 640,
            dap_layer: 1,
        }
    }

    fn parse(line: &str) -> Json {
        Json::parse(line).unwrap()
    }

    #[test]
    fn synthesize_parses_kinds() {
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b = RequestBuilder::new(&m, &g, 5);
        let (id, req) =
            synthesize(&parse(r#"{"id": 7, "kind": "qa"}"#), &m, &g, &mut b).unwrap();
        assert_eq!(id, 7);
        assert_eq!(req.kind, WorkloadKind::Understanding);
        let (_, req) = synthesize(
            &parse(r#"{"id": 1, "kind": "story", "max_new": 12}"#),
            &m,
            &g,
            &mut b,
        )
        .unwrap();
        assert_eq!(req.max_new_tokens, 12);
        assert!(synthesize(&parse(r#"{"kind": "nope"}"#), &m, &g, &mut b).is_err());
        // malformed lines never reach synthesize: ingest rejects them
        assert!(Json::parse("not json").is_err());
    }

    #[test]
    fn kind_errors_list_accepted_values() {
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b = RequestBuilder::new(&m, &g, 5);
        let err = synthesize(&parse(r#"{"id": 3, "kind": "nope"}"#), &m, &g, &mut b)
            .unwrap_err()
            .to_string();
        assert!(err.contains("nope"), "names the bad value: {}", err);
        assert!(err.contains("story") && err.contains("qa"), "lists accepted: {}", err);
        let err = synthesize(&parse(r#"{"id": 3}"#), &m, &g, &mut b)
            .unwrap_err()
            .to_string();
        assert!(err.contains("accepted"), "missing kind lists accepted: {}", err);
        // the error reply the scheduler path sends echoes the id with it
        let reply = error_reply(Some(3), &err);
        let j = Json::parse(&reply).unwrap();
        assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(3));
        assert!(j.get("error").and_then(|v| v.as_str()).unwrap().contains("accepted"));
    }

    #[test]
    fn image_seed_makes_shared_visual_prefixes() {
        let m = meta();
        let g = StoryGrammar::uniform();
        // two different connection-shared builders: same image_seed →
        // identical visual prefix, question selected by "q"
        let mut b1 = RequestBuilder::new(&m, &g, 5);
        let mut b2 = RequestBuilder::new(&m, &g, 999);
        let color = parse(r#"{"id": 1, "kind": "qa", "image_seed": 7, "q": "color"}"#);
        let shape = parse(r#"{"id": 2, "kind": "qa", "image_seed": 7, "q": "shape"}"#);
        let (_, r1) = synthesize(&color, &m, &g, &mut b1).unwrap();
        let (_, r2) = synthesize(&color, &m, &g, &mut b2).unwrap();
        assert_eq!(r1.ids, r2.ids);
        assert_eq!(r1.patches, r2.patches);
        let (_, r3) = synthesize(&shape, &m, &g, &mut b1).unwrap();
        let pre = 1 + m.n_patches;
        assert_eq!(&r3.patches[..pre * m.patch_dim], &r1.patches[..pre * m.patch_dim]);
        assert_ne!(r3.ids, r1.ids, "different question token");
        // unknown q is rejected with the accepted values
        let bad = parse(r#"{"id": 1, "kind": "qa", "image_seed": 7, "q": "size"}"#);
        let err = synthesize(&bad, &m, &g, &mut b1).unwrap_err().to_string();
        assert!(err.contains("size") && err.contains("color"), "{}", err);
    }

    #[test]
    fn dialog_turns_synthesize_distinct_prompts_over_one_image() {
        let m = meta();
        let g = StoryGrammar::uniform();
        let mut b1 = RequestBuilder::new(&m, &g, 5);
        let mut b2 = RequestBuilder::new(&m, &g, 999);
        let t0 = parse(r#"{"id": 1, "kind": "qa", "image_seed": 7, "turn": 0}"#);
        let t2 = parse(r#"{"id": 2, "kind": "qa", "image_seed": 7, "turn": 2}"#);
        let (_, r0) = synthesize(&t0, &m, &g, &mut b1).unwrap();
        let (_, r2) = synthesize(&t2, &m, &g, &mut b1).unwrap();
        let pre = 1 + m.n_patches;
        assert_ne!(r0.ids, r2.ids, "turns are distinct prompts");
        assert_eq!(&r2.patches[..pre * m.patch_dim], &r0.patches[..pre * m.patch_dim]);
        assert!(r2.prompt_len() > r0.prompt_len(), "history grows the prompt");
        // reproducible across connections
        let (_, again) = synthesize(&t2, &m, &g, &mut b2).unwrap();
        assert_eq!(again.ids, r2.ids);
        // negative turns are rejected
        let bad = parse(r#"{"id": 1, "kind": "qa", "image_seed": 7, "turn": -1}"#);
        assert!(synthesize(&bad, &m, &g, &mut b1).is_err());
    }

    #[test]
    fn seed_makes_requests_reproducible() {
        let m = meta();
        let g = StoryGrammar::uniform();
        // two different connection-shared builders, same seeded line
        let mut b1 = RequestBuilder::new(&m, &g, 5);
        let mut b2 = RequestBuilder::new(&m, &g, 999);
        let line = parse(r#"{"id": 1, "kind": "story", "seed": 42}"#);
        let (_, r1) = synthesize(&line, &m, &g, &mut b1).unwrap();
        let (_, r2) = synthesize(&line, &m, &g, &mut b2).unwrap();
        assert_eq!(r1.ids, r2.ids);
        assert_eq!(r1.patches, r2.patches);
        // unseeded requests keep drawing from the shared stream
        let unseeded = parse(r#"{"id": 2, "kind": "story"}"#);
        let (_, u1) = synthesize(&unseeded, &m, &g, &mut b1).unwrap();
        let (_, u2) = synthesize(&unseeded, &m, &g, &mut b2).unwrap();
        assert_ne!(u1.ids, u2.ids);
    }

    #[test]
    fn error_reply_escapes_and_echoes_id() {
        let r = error_reply(Some(9), "bad \"quoted\"\nthing");
        let j = Json::parse(&r).unwrap();
        assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(9));
        assert_eq!(
            j.get("error").and_then(|v| v.as_str()),
            Some("bad \"quoted\"\nthing")
        );
        // id omitted when unknown
        let j = Json::parse(&error_reply(None, "x")).unwrap();
        assert!(j.get("id").is_none());
    }
}
