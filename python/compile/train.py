"""Short artifact-build-time training loop for TinyMM.

Trains the tiny multimodal transformer on the synthetic corpus (data.py) for
a few hundred Adam steps — just enough for structured, sparse attention maps
to emerge (the property HAE relies on). Runs once inside `make artifacts`;
the resulting weights are cached in artifacts/weights.npz. Optax is not
assumed to exist in the image, so Adam is hand-rolled.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .config import MODEL
from .model import init_weights, train_forward


def loss_fn(params, ids, patches, isv, loss_w):
    """Next-token cross-entropy, weighted by loss_w at *target* positions."""
    logits = train_forward(params, ids, patches, isv)      # [N,S,V]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)     # predict t+1 from t
    tgt = ids[:, 1:]
    w = loss_w[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.float32)}


def adam_step(params, grads, state, lr=3e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1.0 - b1 ** t)
    vhat_scale = 1.0 / (1.0 - b2 ** t)
    new = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v)
    return new, {"m": m, "v": v, "t": t}


@jax.jit
def _update(params, opt, ids, patches, isv, lw):
    loss, grads = jax.value_and_grad(loss_fn)(params, ids, patches, isv, lw)
    params, opt = adam_step(params, grads, opt)
    return params, opt, loss


def train(steps: int = 300, batch_size: int = 16, seq_len: int = 96,
          seed: int = 7, log_every: int = 50, verbose: bool = True):
    """Returns (params dict, final loss, loss history)."""
    rng = np.random.default_rng(seed)
    params = init_weights(jax.random.PRNGKey(seed))
    opt = adam_init(params)
    history = []
    t0 = time.time()
    loss = None
    for step in range(steps):
        ids, pat, isv, lw = data.batch(rng, batch_size, seq_len)
        params, opt, loss = _update(params, opt, jnp.asarray(ids),
                                    jnp.asarray(pat), jnp.asarray(isv),
                                    jnp.asarray(lw))
        if step % log_every == 0 or step == steps - 1:
            lv = float(loss)
            history.append((step, lv))
            if verbose:
                print(f"  train step {step:4d}  loss {lv:.4f}  "
                      f"({time.time() - t0:.1f}s)", flush=True)
    return params, float(loss), history


def qa_accuracy(params, n: int = 64, seq_len: int = 32, seed: int = 99) -> float:
    """Sanity metric: greedy answer-token accuracy on held-out QA samples."""
    rng = np.random.default_rng(seed)
    correct = 0
    ids, pat, isv, lw = data.batch(rng, n, seq_len, story_frac=0.0)
    logits = train_forward(params, jnp.asarray(ids), jnp.asarray(pat),
                           jnp.asarray(isv))
    logits = np.asarray(logits)
    for j in range(n):
        # answer position = first loss-weighted position; model predicts it
        # from the previous position's logits
        apos = int(np.argmax(lw[j] > 0))
        pred = int(np.argmax(logits[j, apos - 1]))
        if pred == int(ids[j, apos]):
            correct += 1
    return correct / n
