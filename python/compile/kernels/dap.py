"""L1 — Pallas DAP statistic kernel (paper Eqs. 1 and 3).

Reduces a layer's attention probabilities to the two per-column statistics
Dual-Attention Pruning needs:

  colsum_j = Σ_i w_i · P̄[i, j]      (Eq. 1 — global text→key attention mass)
  colmax_j = max_{i: w_i>0} P̄[i, j] (Eq. 3 — strongest individual text link)

where P̄ is the head-averaged probability matrix and w is the text-row
weight vector (1.0 at valid text query rows). Evaluating the reductions
in-kernel means the [H, S, S] probability tensor never has to leave the
device for the policy decision — only the two [S] vectors do.

Grid: one step per key-column block; each step reduces over all heads and
all query rows. VMEM per step at S=256, block=128: probs slab
H·S·block·4 = 4·256·128·4 = 512 KiB — comfortably inside VMEM and the
reduction is a pure VPU workload (no MXU needed).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_C = 128


def _dap_kernel(p_ref, w_ref, sum_ref, max_ref, *, n_heads):
    """One column-block grid step.

    p_ref:   [H, S, Bc]  probability slab (all heads, all rows, Bc columns)
    w_ref:   [S]         text-row weights
    sum_ref: [Bc]
    max_ref: [Bc]
    """
    p = p_ref[...]                      # [H, S, Bc]
    w = w_ref[...]                      # [S]
    pbar = jnp.sum(p, axis=0) / jnp.float32(n_heads)   # [S, Bc]
    sum_ref[...] = jnp.dot(w, pbar, preferred_element_type=jnp.float32)
    masked = pbar * (w[:, None] > 0)
    max_ref[...] = jnp.max(masked, axis=0)


@functools.partial(jax.jit, static_argnames=("block_c",))
def dap_stats(probs, row_weight, *, block_c: int = DEFAULT_BLOCK_C):
    """DAP column statistics from one layer's attention probabilities.

    Args:
      probs:      [H, S, S] float32 attention probabilities
      row_weight: [S] float32 (1.0 at valid text query rows)
      block_c:    key-column tile width; must divide S.

    Returns:
      colsum: [S], colmax: [S]  (see ref.dap_stats_ref)
    """
    h, s, _ = probs.shape
    if s % block_c != 0:
        block_c = s
    kernel = functools.partial(_dap_kernel, n_heads=h)
    colsum, colmax = pl.pallas_call(
        kernel,
        grid=(s // block_c,),
        in_specs=[
            pl.BlockSpec((h, s, block_c), lambda cc: (0, 0, cc)),
            pl.BlockSpec((s,), lambda cc: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_c,), lambda cc: (cc,)),
            pl.BlockSpec((block_c,), lambda cc: (cc,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s,), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        interpret=True,
    )(probs, row_weight)
    return colsum, colmax
