"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy ops only. pytest (python/tests/test_kernels.py)
sweeps shapes/dtypes with hypothesis and asserts allclose between kernel and
oracle — this is the core L1 correctness signal.
"""

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, mask):
    """Multi-head attention over a full (prefill) sequence.

    Args:
      q, k, v: [H, S, Dh] float32
      mask:    [S, S] additive mask (0 for visible, large negative otherwise)

    Returns:
      out:   [H, S, Dh]
      probs: [H, S, S] post-softmax attention probabilities
    """
    dh = q.shape[-1]
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(dh))
    scores = scores + mask[None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hqk,hkd->hqd", probs, v)
    return out, probs


def dap_stats_ref(probs, row_weight):
    """DAP statistics (paper Eqs. 1 and 3) from layer attention probs.

    Head-averaged attention matrix P̄[i, j]; for each column (key) j:
      colsum_j = Σ_i w_i · P̄[i, j]   — Eq. 1 global text→key mass
      colmax_j = max_{i : w_i > 0} P̄[i, j]   — Eq. 3 individual max

    Args:
      probs:      [H, S, S] attention probabilities (query i, key j)
      row_weight: [S] float32 — 1.0 for valid *text* query rows, else 0.0

    Returns:
      colsum: [S], colmax: [S]
    """
    pbar = jnp.mean(probs, axis=0)                       # [S, S]
    colsum = jnp.einsum("i,ij->j", row_weight, pbar)     # [S]
    colmax = jnp.max(pbar * (row_weight[:, None] > 0), axis=0)
    return colsum, colmax


def decode_attention_ref(q, k_cache, v_cache, valid):
    """Single-token batched decode attention.

    Args:
      q:        [B, H, Dh]
      k_cache:  [B, C, H, Dh]
      v_cache:  [B, C, H, Dh]
      valid:    [B, C] float32 — 1.0 where the cache slot is attendable

    Returns:
      out:    [B, H, Dh]
      probs:  [B, H, C]
    """
    dh = q.shape[-1]
    scores = jnp.einsum("bhd,bchd->bhc", q, k_cache) / jnp.sqrt(jnp.float32(dh))
    neg = jnp.float32(-1e9)
    scores = jnp.where(valid[:, None, :] > 0, scores, neg)
    probs = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows can't happen in practice (the new token always
    # attends to itself) but guard against NaN for the property tests:
    probs = jnp.where(jnp.sum(valid, axis=-1)[:, None, None] > 0, probs, 0.0)
    out = jnp.einsum("bhc,bchd->bhd", probs, v_cache)
    return out, probs


def sparsity_rates_ref(probs, is_vision, valid, eps):
    """Paper Appendix Eq. 7 — threshold sparsity of one layer's attention.

    Computed over the valid causal region only (entries at or below the
    diagonal with both query and key valid), split into overall / visual-key
    / text-key components as in Fig. 3.

    Args:
      probs:     [H, S, S]
      is_vision: [S] float32 — 1.0 at vision token positions
      valid:     [S] float32 — 1.0 at valid (non-pad) positions
      eps:       scalar threshold

    Returns:
      [3] float32 — (overall, visual, text) sparsity rates.
    """
    s = probs.shape[-1]
    causal = jnp.tril(jnp.ones((s, s), jnp.float32))
    region = causal * valid[:, None] * valid[None, :]          # [S, S]
    pbar = jnp.mean(probs, axis=0)
    small = (pbar <= eps).astype(jnp.float32) * region

    def rate(col_mask):
        denom = jnp.sum(region * col_mask[None, :])
        num = jnp.sum(small * col_mask[None, :])
        return jnp.where(denom > 0, num / denom, 0.0)

    overall = rate(valid)
    visual = rate(is_vision * valid)
    text = rate((1.0 - is_vision) * valid)
    return jnp.stack([overall, visual, text])
