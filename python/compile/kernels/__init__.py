"""L1 Pallas kernels (build-time only; lowered into the AOT HLO artifacts)."""

from . import attention, dap, ref  # noqa: F401
