"""L1 — Pallas fused prefill attention kernel.

The prefill attention (S² work over mixed vision+text sequences) is the
compute hot-spot of the serving stack; HAE additionally needs the post-
softmax probabilities of layer 0 to compute the DAP statistics (paper
Eqs. 1/3), so the kernel emits both the attention output and the
probability block.

Hardware adaptation (DESIGN.md §2): the paper's CUDA implementation stages
K/V through shared memory per threadblock; here the BlockSpec index maps
express the HBM→VMEM schedule instead. The grid iterates (head, q-block);
each step holds one [Bq, Dh] query tile plus the full [S, Dh] K/V panels for
that head in VMEM — at the largest bucket (S=256, Dh=32, f32) that is
  Q tile   64·32·4   =   8 KiB
  K panel 256·32·4   =  32 KiB
  V panel 256·32·4   =  32 KiB
  mask    64·256·4   =  64 KiB
  probs   64·256·4   =  64 KiB
  out      64·32·4   =   8 KiB
≈ 208 KiB « 16 MiB VMEM, and the two matmuls are MXU-shaped ([64,32]×[32,S]
and [64,S]×[S,32] — the contraction dims are multiples of 8×128 packing for
f32 on real TPU; on this CPU target the kernel runs under interpret=True).

The kernel MUST be lowered with interpret=True: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Query rows processed per grid step. 64 divides every prefill bucket
# (64/128/256) and keeps the probs tile at 64 KiB.
DEFAULT_BLOCK_Q = 64


def _attention_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, p_ref, *, scale):
    """One (head, q-block) grid step.

    q_ref:    [Bq, Dh]   query tile for this head / q block
    k_ref:    [S, Dh]    full key panel for this head
    v_ref:    [S, Dh]    full value panel for this head
    mask_ref: [Bq, S]    additive mask tile (shared across heads)
    o_ref:    [Bq, Dh]   output tile
    p_ref:    [Bq, S]    probability tile
    """
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    scores = scores + mask_ref[...]
    # numerically-stable softmax on the row axis
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    z = jnp.sum(e, axis=-1, keepdims=True)
    p = e / z
    p_ref[...] = p
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_q",))
def attention(q, k, v, mask, *, block_q: int = DEFAULT_BLOCK_Q):
    """Fused multi-head prefill attention.

    Args:
      q, k, v: [H, S, Dh] float32
      mask:    [S, S] additive mask (0 visible / -1e9 hidden); carries both
               causality and pad-validity, so the kernel itself is
               mask-agnostic.
      block_q: query tile height; must divide S.

    Returns:
      out:   [H, S, Dh]
      probs: [H, S, S]
    """
    h, s, dh = q.shape
    if s % block_q != 0:
        # shapes are static at trace time, so plain python control flow is fine
        block_q = s
    scale = 1.0 / (dh ** 0.5)
    grid = (h, s // block_q)

    kernel = functools.partial(_attention_kernel, scale=scale)
    out, probs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, dh), lambda hh, qq: (hh, qq, 0)),
            pl.BlockSpec((None, s, dh), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((None, s, dh), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((block_q, s), lambda hh, qq: (qq, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_q, dh), lambda hh, qq: (hh, qq, 0)),
            pl.BlockSpec((None, block_q, s), lambda hh, qq: (hh, qq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, s, dh), jnp.float32),
            jax.ShapeDtypeStruct((h, s, s), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, mask)
    return out, probs


def _decode_attention_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, p_ref, *, scale):
    """One (batch, head) grid step of single-token decode attention.

    q_ref:     [Dh]     query vector
    k_ref:     [C, Dh]  key cache panel
    v_ref:     [C, Dh]  value cache panel
    valid_ref: [C]      1.0 where slot attendable
    o_ref:     [Dh]
    p_ref:     [C]
    """
    q = q_ref[...]
    k = k_ref[...]
    valid = valid_ref[...]
    scores = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale
    scores = jnp.where(valid > 0, scores, jnp.float32(-1e9))
    m = jnp.max(scores)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e)
    p_ref[...] = p
    o_ref[...] = jnp.dot(p, v_ref[...], preferred_element_type=jnp.float32)


@jax.jit
def decode_attention(q, k_cache, v_cache, valid):
    """Batched single-token decode attention (see ref.decode_attention_ref).

    Args:
      q:       [B, H, Dh]
      k_cache: [B, C, H, Dh]
      v_cache: [B, C, H, Dh]
      valid:   [B, C] float32

    Returns:
      out:   [B, H, Dh]
      probs: [B, H, C]
    """
    b, hh, dh = q.shape
    c = k_cache.shape[1]
    scale = 1.0 / (dh ** 0.5)
    # reorder caches head-major so each grid step reads a contiguous panel
    k_hm = jnp.transpose(k_cache, (0, 2, 1, 3))  # [B, H, C, Dh]
    v_hm = jnp.transpose(v_cache, (0, 2, 1, 3))

    kernel = functools.partial(_decode_attention_kernel, scale=scale)
    out, probs = pl.pallas_call(
        kernel,
        grid=(b, hh),
        in_specs=[
            pl.BlockSpec((None, None, dh), lambda bb, h2: (bb, h2, 0)),
            pl.BlockSpec((None, None, c, dh), lambda bb, h2: (bb, h2, 0, 0)),
            pl.BlockSpec((None, None, c, dh), lambda bb, h2: (bb, h2, 0, 0)),
            pl.BlockSpec((None, c), lambda bb, h2: (bb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, dh), lambda bb, h2: (bb, h2, 0)),
            pl.BlockSpec((None, None, c), lambda bb, h2: (bb, h2, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hh, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, hh, c), jnp.float32),
        ],
        interpret=True,
    )(q, k_hm, v_hm, valid)
    return out, probs
