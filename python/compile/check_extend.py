"""Numeric equivalence check for the chunked extend graph.

The extend executable must agree with the graphs it replaces:

  1. a suffix recomputed through `extend_fn` (in chunks, against the
     unpruned prefix KV) reproduces the KV rows, last-position logits and
     DAP column statistics of a cold `prefill_fn` over the whole prompt;
  2. chunk size 1..S all agree with the one-token `decode_fn` loop;
  3. pad rows (n_new < S) never influence the valid rows.

Tolerances are ULP-scale (the graphs reduce in different float orders —
the same caveat the engine documents for partial warm starts); the DAP
row accumulation itself is exact once the rows agree.

Usage:  python -m compile.check_extend      (exit 0 = all checks pass)
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np

from .config import MODEL
from . import model as M

ATOL = 2e-4
SEED = 3


def build_prompt(rng, cfg, n_vis, n_suffix):
    """[BOS][vision×n_vis][text×n_suffix] — the partial warm-start shape."""
    n = 1 + n_vis + n_suffix
    ids = np.zeros(n, np.int32)
    ids[0] = 1  # BOS
    ids[1:1 + n_vis] = 3  # IMG placeholder
    ids[1 + n_vis:] = rng.integers(4, cfg.vocab, n_suffix)
    is_vision = np.zeros(n, np.float32)
    is_vision[1:1 + n_vis] = 1.0
    patches = np.zeros((n, cfg.patch_dim), np.float32)
    patches[1:1 + n_vis] = rng.normal(size=(n_vis, cfg.patch_dim)).astype(np.float32)
    return ids, patches, is_vision


def run_extend(params_flat, cfg, ids, p, n, k_full, v_full, chunk, s_bucket,
               scramble_pads=False):
    """Replay the suffix [p, n) through extend_fn in `chunk`-token calls.

    Returns (k_rows[L, n-p, H, Dh], v_rows, last_logits, dap_row_list)
    where dap_row_list[i] is suffix row i's contributions to columns
    0..p+i (cache part + intra part + self), host-accumulated exactly
    like the engine does.
    """
    extend = M.extend_fn(cfg)
    c = s_bucket * 4  # any capacity ≥ n works; mask hides the rest
    k_cache = np.zeros((1, cfg.n_layers, c, cfg.n_heads, cfg.d_head), np.float32)
    v_cache = np.zeros_like(k_cache)
    k_cache[0, :, :p] = k_full[:, :p]
    v_cache[0, :, :p] = v_full[:, :p]
    k_rows = np.zeros((cfg.n_layers, n - p, cfg.n_heads, cfg.d_head), np.float32)
    v_rows = np.zeros_like(k_rows)
    dap_row_list = []
    last_logits = None
    t = p
    while t < n:
        step = min(chunk, n - t)
        tok = np.zeros((1, s_bucket), np.int32)
        pos = np.zeros((1, s_bucket), np.int32)
        tok[0, :step] = ids[t:t + step]
        pos[0, :step] = np.arange(t, t + step)
        if scramble_pads and step < s_bucket:
            tok[0, step:] = 7
            pos[0, step:] = 1
        out = extend(*params_flat, jnp.asarray(tok), jnp.asarray(pos),
                     jnp.asarray(k_cache), jnp.asarray(v_cache),
                     jnp.asarray([t], jnp.int32), jnp.asarray([step], jnp.int32))
        logits, k_new, v_new, dap_rows = map(np.asarray, out)
        for i in range(step):
            k_rows[:, t - p + i] = k_new[0, :, i]
            v_rows[:, t - p + i] = v_new[0, :, i]
            k_cache[0, :, t + i] = k_new[0, :, i]
            v_cache[0, :, t + i] = v_new[0, :, i]
            # cache part then intra part — the engine's accumulation order
            row = np.concatenate([dap_rows[0, i, :t], dap_rows[0, i, c:c + i + 1]])
            dap_row_list.append(row)
        if t + step == n:
            last_logits = logits[0]
        t += step
    return k_rows, v_rows, last_logits, dap_row_list


def main():
    cfg = MODEL
    rng = np.random.default_rng(SEED)
    params = M.init_weights(jax.random.PRNGKey(SEED), cfg)
    flat = M.params_tuple(params)
    n_vis, n_suffix = 6, 11
    ids, patches, is_vision = build_prompt(rng, cfg, n_vis, n_suffix)
    n = len(ids)
    p = 1 + n_vis  # one past the last vision token

    # cold reference: plain-jnp prefill over the whole prompt (the pallas
    # kernels run interpreted on CPU and agree with the reference — this
    # check targets the extend graph, not the kernels)
    prefill = M.prefill_fn(cfg, use_pallas=False)
    out = prefill(*flat, jnp.asarray(ids), jnp.asarray(patches),
                  jnp.asarray(is_vision), jnp.int32(n), jnp.int32(p))
    logits_ref, k_ref, v_ref, dap_sum, dap_max, dap_psum, dap_pmax = map(np.asarray, out)

    failures = []

    def check(name, a, b, atol=ATOL):
        err = float(np.max(np.abs(np.asarray(a) - np.asarray(b)))) if np.size(a) else 0.0
        ok = err <= atol
        print(f"  {'ok ' if ok else 'FAIL'} {name:<46} max|Δ| = {err:.2e}")
        if not ok:
            failures.append(name)

    for chunk, s_bucket in [(1, 8), (4, 8), (8, 8), (n_suffix, 16)]:
        print(f"extend chunk={chunk} (bucket {s_bucket}) vs cold prefill:")
        k_rows, v_rows, logits, rows = run_extend(
            flat, cfg, ids, p, n, k_ref, v_ref, chunk, s_bucket)
        # prefill stores K as [L, S, H, Dh]
        check("suffix K rows", k_rows, k_ref[:, p:n])
        check("suffix V rows", v_rows, v_ref[:, p:n])
        check("last-position logits", logits, logits_ref)
        # reconstruct the request's own DAP statistics: cached prefix-row
        # contributions (dap_psum/dap_pmax) + the recomputed suffix rows
        colsum = np.zeros(n, np.float32)
        colmax = np.zeros(n, np.float32)
        colsum[:] = dap_psum[:n]
        colmax[:] = dap_pmax[:n]
        for i, row in enumerate(rows):
            m = len(row)
            colsum[:m] += row
            colmax[:m] = np.maximum(colmax[:m], row)
            assert m == p + i + 1, "row covers columns 0..=its own position"
        check("replayed Eq.1 column sums", colsum, dap_sum[:n])
        check("replayed Eq.3 column maxes", colmax, dap_max[:n])

    # decode-loop agreement: chunk=1 through extend ≈ the decode graph
    print("extend chunk=1 vs one-token decode loop:")
    decode = M.decode_fn(cfg)
    c = 64
    k_cache = np.zeros((1, cfg.n_layers, c, cfg.n_heads, cfg.d_head), np.float32)
    v_cache = np.zeros_like(k_cache)
    k_cache[0, :, :p] = k_ref[:, :p]
    v_cache[0, :, :p] = v_ref[:, :p]
    dec_rows = []
    dec_logits = None
    for t in range(p, n):
        out = decode(*flat, jnp.asarray([ids[t]], jnp.int32),
                     jnp.asarray([t], jnp.int32), jnp.asarray(k_cache),
                     jnp.asarray(v_cache), jnp.asarray([t], jnp.int32))
        logits, k_new, v_new, _, _, _, dap_row, dap_self = map(np.asarray, out)
        k_cache[0, :, t] = k_new[0]
        v_cache[0, :, t] = v_new[0]
        dec_rows.append(np.concatenate([dap_row[0, :t], dap_self[:1]]))
        dec_logits = logits[0]
    k1, v1, l1, rows1 = run_extend(flat, cfg, ids, p, n, k_ref, v_ref, 1, 8)
    check("decode vs extend logits", l1, dec_logits)
    check("decode vs extend K", k1, k_cache[0, :, p:n])
    for i, (a, b) in enumerate(zip(rows1, dec_rows)):
        check(f"decode vs extend dap row {i}", a, b)

    # pad independence: garbage in rows ≥ n_new must not leak into valid rows
    print("pad-row independence (n_new < S, scrambled pads):")
    ka, va, la, ra = run_extend(flat, cfg, ids, p, n, k_ref, v_ref, 3, 8)
    kb, vb, lb, rb = run_extend(flat, cfg, ids, p, n, k_ref, v_ref, 3, 8,
                                scramble_pads=True)
    check("K rows unchanged", ka, kb, atol=0.0)
    check("logits unchanged", la, lb, atol=0.0)
    for i, (a, b) in enumerate(zip(ra, rb)):
        check(f"dap row {i} unchanged", a, b, atol=0.0)

    if failures:
        print(f"\ncheck_extend: {len(failures)} FAILED: {failures}")
        return 1
    print("\ncheck_extend: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
