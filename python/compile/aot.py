"""AOT entry point — trains TinyMM and lowers all graph variants to HLO text.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Emits, into the artifacts directory:
  weights.npz       — cached trained parameters (skip retraining when fresh)
  weights.bin       — flat little-endian f32 in model.WEIGHT_NAMES order
  manifest.json     — model config + weight table + artifact table (the
                      contract the rust runtime validates at startup)
  prefill_s{S}.hlo.txt
  decode_b{B}_c{C}.hlo.txt
  extend_b{B}_s{S}_c{C}.hlo.txt
  analysis_s{S}.hlo.txt

Set HAE_SMALL_ARTIFACTS=1 for the trimmed bucket grid CI builds (same
model and training, fewer graphs — see config.SMALL_ARTIFACTS).

Interchange format is HLO **text**, not serialized HloModuleProto: jax≥0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .config import MODEL, ARTIFACTS, SMALL, manifest_dict
from . import model as M
from . import train as T

SEED = 7
TRAIN_STEPS = int(os.environ.get("HAE_TRAIN_STEPS", "300"))


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def weight_specs():
    return [jax.ShapeDtypeStruct(shape, jnp.float32)
            for shape in M.weight_shapes().values()]


def source_fingerprint() -> str:
    """Hash of the compile-path sources — invalidates cached artifacts.

    The build-shaping environment (bucket grid, training length) is part
    of the hash: switching HAE_SMALL_ARTIFACTS or HAE_TRAIN_STEPS must
    not be mistaken for an up-to-date build.
    """
    h = hashlib.sha256()
    pkg = os.path.dirname(__file__)
    for root, _, files in sorted(os.walk(pkg)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    h.update(b"small" if SMALL else b"full")
    h.update(str(TRAIN_STEPS).encode())
    return h.hexdigest()[:16]


def get_params(out_dir: str, verbose=True):
    cache = os.path.join(out_dir, "weights.npz")
    if os.path.exists(cache):
        z = np.load(cache)
        if z.get("fingerprint_steps") == TRAIN_STEPS and all(
                n in z for n in M.WEIGHT_NAMES):
            if verbose:
                print("aot: reusing cached weights.npz", flush=True)
            return {n: jnp.asarray(z[n]) for n in M.WEIGHT_NAMES}
    if verbose:
        print(f"aot: training TinyMM for {TRAIN_STEPS} steps…", flush=True)
    params, loss, _ = T.train(steps=TRAIN_STEPS, seed=SEED, verbose=verbose)
    acc = T.qa_accuracy(params)
    if verbose:
        print(f"aot: final loss {loss:.4f}, QA answer accuracy {acc:.2%}",
              flush=True)
    np.savez(cache, fingerprint_steps=TRAIN_STEPS,
             **{n: np.asarray(params[n]) for n in M.WEIGHT_NAMES})
    return params


def dump_weights(params, out_dir: str):
    """weights.bin: flat f32 LE in WEIGHT_NAMES order; returns table entries."""
    entries = []
    offset = 0
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for name in M.WEIGHT_NAMES:
            arr = np.ascontiguousarray(np.asarray(params[name], np.float32))
            f.write(arr.tobytes())
            entries.append({
                "name": name,
                "shape": list(arr.shape),
                "offset": offset,
                "numel": int(arr.size),
            })
            offset += arr.size * 4
    return entries


def lower_all(out_dir: str, verbose=True):
    cfg = MODEL
    art = ARTIFACTS
    wspecs = weight_specs()
    table = []

    def emit(name, fn, extra_specs):
        t0 = time.time()
        # keep_unused=True: the weight-buffer list is a fixed ABI shared by
        # all executables — decode doesn't use w_patch/b_patch but must
        # still accept them.
        lowered = jax.jit(fn, keep_unused=True).lower(*wspecs, *extra_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        if verbose:
            print(f"aot: {name}.hlo.txt  ({len(text)/1e6:.2f} MB, "
                  f"{time.time()-t0:.1f}s)", flush=True)
        return path

    i32 = jnp.int32
    f32 = jnp.float32

    for s in art.prefill_buckets:
        specs = [
            jax.ShapeDtypeStruct((s,), i32),                     # ids
            jax.ShapeDtypeStruct((s, cfg.patch_dim), f32),       # patches
            jax.ShapeDtypeStruct((s,), f32),                     # is_vision
            jax.ShapeDtypeStruct((), i32),                       # n_tokens
            jax.ShapeDtypeStruct((), i32),                       # n_prefix
        ]
        emit(f"prefill_s{s}", M.prefill_fn(cfg), specs)
        table.append({"name": f"prefill_s{s}", "kind": "prefill", "bucket": s})

    for b in art.decode_batches:
        for c in art.decode_capacities:
            specs = [
                jax.ShapeDtypeStruct((b,), i32),                 # token
                jax.ShapeDtypeStruct((b,), i32),                 # pos
                jax.ShapeDtypeStruct(
                    (b, cfg.n_layers, c, cfg.n_heads, cfg.d_head), f32),  # K
                jax.ShapeDtypeStruct(
                    (b, cfg.n_layers, c, cfg.n_heads, cfg.d_head), f32),  # V
                jax.ShapeDtypeStruct((b,), i32),                 # length
            ]
            emit(f"decode_b{b}_c{c}", M.decode_fn(cfg), specs)
            table.append({"name": f"decode_b{b}_c{c}", "kind": "decode",
                          "batch": b, "capacity": c})

    for b in art.extend_batches:
        for s in art.extend_chunks:
            for c in art.decode_capacities:
                specs = [
                    jax.ShapeDtypeStruct((b, s), i32),           # token
                    jax.ShapeDtypeStruct((b, s), i32),           # pos
                    jax.ShapeDtypeStruct(
                        (b, cfg.n_layers, c, cfg.n_heads, cfg.d_head), f32),  # K
                    jax.ShapeDtypeStruct(
                        (b, cfg.n_layers, c, cfg.n_heads, cfg.d_head), f32),  # V
                    jax.ShapeDtypeStruct((b,), i32),             # length
                    jax.ShapeDtypeStruct((b,), i32),             # n_new
                ]
                emit(f"extend_b{b}_s{s}_c{c}", M.extend_fn(cfg), specs)
                table.append({"name": f"extend_b{b}_s{s}_c{c}", "kind": "extend",
                              "batch": b, "chunk": s, "capacity": c})

    for s in art.analysis_buckets:
        specs = [
            jax.ShapeDtypeStruct((s,), i32),
            jax.ShapeDtypeStruct((s, cfg.patch_dim), f32),
            jax.ShapeDtypeStruct((s,), f32),
            jax.ShapeDtypeStruct((), i32),                       # n_tokens
            jax.ShapeDtypeStruct((), i32),                       # n_prefix
        ]
        emit(f"analysis_s{s}", M.prefill_fn(cfg, collect_layers=True), specs)
        table.append({"name": f"analysis_s{s}", "kind": "analysis", "bucket": s})

    return table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)
    verbose = not args.quiet

    fp = source_fingerprint()
    stamp = os.path.join(out_dir, "fingerprint.txt")
    manifest_path = os.path.join(out_dir, "manifest.json")
    if os.path.exists(stamp) and os.path.exists(manifest_path):
        if open(stamp).read().strip() == fp:
            print("aot: artifacts up to date (fingerprint match); nothing to do")
            return

    params = get_params(out_dir, verbose)
    weight_entries = dump_weights(params, out_dir)

    # Export the story grammar so the rust workload generator samples from
    # the exact distribution the model was trained on (data contract).
    from . import data as D
    trans = np.ascontiguousarray(D.story_transition(), np.float32)
    with open(os.path.join(out_dir, "grammar.bin"), "wb") as f:
        f.write(trans.tobytes())

    artifact_table = lower_all(out_dir, verbose)

    manifest = manifest_dict(weight_entries, SEED, TRAIN_STEPS)
    manifest["artifact_table"] = artifact_table
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    with open(stamp, "w") as f:
        f.write(fp)
    print(f"aot: wrote {len(artifact_table)} HLO artifacts + weights "
          f"({sum(e['numel'] for e in weight_entries)} params) to {out_dir}")


if __name__ == "__main__":
    sys.exit(main())
