"""Synthetic multimodal corpus for training TinyMM at artifact-build time.

This is the stand-in for the proprietary/benchmark data the paper uses
(LLaVA eval suites, MMMU, Seed-Story) — see DESIGN.md §3. It is engineered
so that a briefly-trained model develops exactly the attention structure HAE
exploits:

* "images" are 16-patch feature grids where only 2–4 patches carry the class
  signal (a color×shape prototype) and the rest are background noise —
  text→vision attention therefore concentrates on few columns (high visual
  sparsity, paper Fig. 3);
* QA samples force answer positions to consult the informative patches;
* story samples have local n-gram text structure with sporadic references to
  the image class, keeping long-range text attention diffuse relative to
  visual attention (paper Fig. 2 variance gap).

The rust workload generator (rust/src/workload/) re-implements the same
construction with the same token-id layout so serving-time requests come
from the distribution the model was trained on. Keep the two in sync — the
layout constants below are mirrored in rust/src/model/vocab.rs.
"""

import numpy as np

from .config import MODEL, ARTIFACTS

# --- token-id layout (mirror of rust/src/model/vocab.rs) -------------------
PAD, BOS, EOS, IMG = 0, 1, 2, 3
Q_COLOR, Q_SHAPE = 8, 9          # question-type tokens
ANS_MARK = 10                    # "A:" marker
STORY_MARK = 11                  # story-segment marker
COLOR_BASE = 16                  # 8 color words: 16..23
SHAPE_BASE = 24                  # 8 shape words: 24..31
STORY_BASE = 64                  # 160 story words: 64..223
N_COLORS, N_SHAPES = 8, 8
N_STORY_WORDS = 160

N_PATCHES = MODEL.n_patches
PATCH_DIM = MODEL.patch_dim
SIGNAL_GAIN = 3.0                # prototype amplitude vs unit noise


def class_prototype(color: int, shape: int) -> np.ndarray:
    """Deterministic patch-space prototype for a (color, shape) class."""
    proto = np.zeros(PATCH_DIM, np.float32)
    proto[color] = SIGNAL_GAIN
    proto[N_COLORS + shape] = SIGNAL_GAIN
    # a couple of correlated dims so the projector has something to learn
    proto[16 + (color * N_SHAPES + shape) % 8] = SIGNAL_GAIN / 2
    return proto


def make_image(rng: np.random.Generator, color: int, shape: int):
    """16 patches, 2–4 informative; returns (patches[NP,PD], informative mask)."""
    patches = rng.standard_normal((N_PATCHES, PATCH_DIM)).astype(np.float32) * 0.5
    n_info = int(rng.integers(2, 5))
    info_idx = rng.choice(N_PATCHES, size=n_info, replace=False)
    proto = class_prototype(color, shape)
    for i in info_idx:
        patches[i] += proto + rng.standard_normal(PATCH_DIM).astype(np.float32) * 0.2
    mask = np.zeros(N_PATCHES, bool)
    mask[info_idx] = True
    return patches, mask


def _story_transition(rng: np.random.Generator):
    """Order-1 markov chain over the story vocabulary, sparse rows."""
    trans = np.zeros((N_STORY_WORDS, N_STORY_WORDS), np.float32)
    for i in range(N_STORY_WORDS):
        nxt = rng.choice(N_STORY_WORDS, size=6, replace=False)
        probs = rng.dirichlet(np.ones(6)).astype(np.float32)
        trans[i, nxt] = probs
    return trans


_STORY_TRANS = None


def story_transition() -> np.ndarray:
    """Global story grammar — fixed seed so python and rust agree."""
    global _STORY_TRANS
    if _STORY_TRANS is None:
        _STORY_TRANS = _story_transition(np.random.default_rng(1234))
    return _STORY_TRANS


def qa_sample(rng: np.random.Generator, seq_len: int):
    """[BOS][IMG×16][Q_attr][ANS][answer][EOS] padded to seq_len."""
    color = int(rng.integers(N_COLORS))
    shape = int(rng.integers(N_SHAPES))
    patches, _ = make_image(rng, color, shape)
    ask_color = bool(rng.integers(2))
    q_tok = Q_COLOR if ask_color else Q_SHAPE
    a_tok = (COLOR_BASE + color) if ask_color else (SHAPE_BASE + shape)

    ids = np.full(seq_len, PAD, np.int32)
    pat = np.zeros((seq_len, PATCH_DIM), np.float32)
    isv = np.zeros(seq_len, np.float32)
    loss_w = np.zeros(seq_len, np.float32)

    i = 0
    ids[i] = BOS; i += 1
    ids[i:i + N_PATCHES] = IMG
    pat[i:i + N_PATCHES] = patches
    isv[i:i + N_PATCHES] = 1.0
    i += N_PATCHES
    ids[i] = q_tok; i += 1
    ids[i] = ANS_MARK
    loss_w[i] = 1.0               # predict the scaffold token from Q
    i += 1
    ids[i] = a_tok
    loss_w[i] = 1.0               # predict the answer token
    i += 1
    ids[i] = EOS
    loss_w[i] = 1.0
    i += 1
    return ids, pat, isv, loss_w, i


def story_sample(rng: np.random.Generator, seq_len: int, n_segments: int = 3,
                 seg_text: int = 14):
    """[BOS] ([IMG×16][STORY][w…])×n padded to seq_len; loss on story text."""
    trans = story_transition()
    ids = np.full(seq_len, PAD, np.int32)
    pat = np.zeros((seq_len, PATCH_DIM), np.float32)
    isv = np.zeros(seq_len, np.float32)
    loss_w = np.zeros(seq_len, np.float32)

    i = 0
    ids[i] = BOS; i += 1
    for _ in range(n_segments):
        if i + N_PATCHES + 1 + seg_text >= seq_len:
            break
        color = int(rng.integers(N_COLORS))
        shape = int(rng.integers(N_SHAPES))
        patches, _ = make_image(rng, color, shape)
        ids[i:i + N_PATCHES] = IMG
        pat[i:i + N_PATCHES] = patches
        isv[i:i + N_PATCHES] = 1.0
        i += N_PATCHES
        ids[i] = STORY_MARK
        loss_w[i] = 1.0           # predict the segment marker from the image
        i += 1
        # first two words reference the image class (cross-modal link)
        ids[i] = COLOR_BASE + color; loss_w[i] = 1.0; i += 1
        ids[i] = SHAPE_BASE + shape; loss_w[i] = 1.0; i += 1
        w = int(rng.integers(N_STORY_WORDS))
        for _ in range(seg_text - 2):
            ids[i] = STORY_BASE + w
            loss_w[i] = 1.0
            i += 1
            w = int(rng.choice(N_STORY_WORDS, p=trans[w]))
    if i < seq_len:
        ids[i] = EOS
        loss_w[i] = 1.0
        i += 1
    return ids, pat, isv, loss_w, i


def batch(rng: np.random.Generator, n: int, seq_len: int, story_frac: float = 0.5):
    """Mixed training batch: (ids[N,S], patches[N,S,PD], isv[N,S], loss_w[N,S])."""
    ids = np.zeros((n, seq_len), np.int32)
    pat = np.zeros((n, seq_len, PATCH_DIM), np.float32)
    isv = np.zeros((n, seq_len), np.float32)
    lw = np.zeros((n, seq_len), np.float32)
    for j in range(n):
        if rng.random() < story_frac:
            s = story_sample(rng, seq_len)
        else:
            s = qa_sample(rng, seq_len)
        ids[j], pat[j], isv[j], lw[j] = s[0], s[1], s[2], s[3]
    return ids, pat, isv, lw
