"""Shared model / artifact configuration for the TinyMM multimodal LM.

This is the single source of truth for the shapes baked into the AOT
artifacts. `aot.py` serialises it into `artifacts/manifest.json`, which the
rust runtime reads at startup — the two sides never have to agree by
convention alone.

TinyMM is the stand-in for LLaVA-1.5 / Phi3.5-Vision in this reproduction
(see DESIGN.md §3): a small decoder-only transformer with a learned patch
projector in front, trained briefly at artifact-build time on a synthetic
multimodal corpus so that its attention maps exhibit the heterogeneous
visual/text sparsity the HAE paper exploits.
"""

import os
from dataclasses import dataclass, field, asdict
from typing import List


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_head: int = 32
    d_mlp: int = 256
    patch_dim: int = 32          # raw feature dim of one image patch
    n_patches: int = 16          # visual tokens per image
    max_pos: int = 640           # positional table size (>= decode capacity)
    # Which layer's attention feeds the DAP statistics. The paper reads its
    # "first layer" of a 32-layer LLM; at TinyMM's 4-layer depth layer 0 is
    # still positional and the first semantically structured attention is
    # layer 1 (DESIGN.md §Hardware-Adaptation).
    dap_layer: int = 1

    @property
    def d_attn(self) -> int:
        return self.n_heads * self.d_head


@dataclass(frozen=True)
class ArtifactConfig:
    """Static shapes compiled into the PJRT executables."""

    prefill_buckets: List[int] = field(default_factory=lambda: [64, 128, 256])
    decode_batches: List[int] = field(default_factory=lambda: [1, 4])
    # decode-time KV capacity buckets; the scheduler picks the smallest
    # bucket that fits the live cache length (eviction → smaller bucket →
    # faster step — the serving-side payoff of HAE)
    decode_capacities: List[int] = field(default_factory=lambda: [128, 256, 384, 512])
    analysis_buckets: List[int] = field(default_factory=lambda: [128, 256])
    cache_capacity: int = 512    # max decode-time KV slots per request (C)
    # chunked-extend executables (extend_b{B}_s{S}_c{C}): prefill-with-
    # KV-cache over S new token rows against a C-slot cache. Partial
    # warm starts recompute their text suffix through these in chunks of
    # --extend-chunk instead of one token per decode call; shorter
    # chunks run padded against the smallest bucket that fits
    extend_batches: List[int] = field(default_factory=lambda: [1])
    extend_chunks: List[int] = field(default_factory=lambda: [8, 32])

    # special token ids (must match rust/src/model/tokenizer.rs)
    pad_id: int = 0
    bos_id: int = 1
    eos_id: int = 2
    img_id: int = 3              # placeholder id at vision positions


MODEL = ModelConfig()


def _env_flag(name: str) -> bool:
    """Explicit truthy set only: "false"/"off"/garbage never silently
    flips a build-shaping flag on."""
    return os.environ.get(name, "").strip().lower() in ("1", "true", "yes", "on")


# The small/test artifact set CI builds (HAE_SMALL_ARTIFACTS=1): the SAME
# model and training (the byte-identity asserts need trained attention,
# where thresholds and greedy argmax sit far from ties), but a trimmed
# bucket grid — fewer graphs to lower at build time and fewer PJRT
# compiles at test time. Every workload the test suites synthesize still
# fits: prompts ≤ 256 tokens, live caches ≤ 512 slots.
SMALL_ARTIFACTS = ArtifactConfig(
    prefill_buckets=[64, 256],
    decode_batches=[1, 4],
    decode_capacities=[128, 512],
    analysis_buckets=[128],
    extend_batches=[1],
    extend_chunks=[8, 32],
)

# normalized once here; aot.py hashes this decision (not the raw env
# string) into the artifact fingerprint
SMALL = _env_flag("HAE_SMALL_ARTIFACTS")

ARTIFACTS = SMALL_ARTIFACTS if SMALL else ArtifactConfig()

# Sparsity threshold used by the paper for Fig. 3 (Appendix Eq. 7).
SPARSITY_EPS = 1e-4


def manifest_dict(weight_entries, seed: int, train_steps: int) -> dict:
    return {
        "model": asdict(MODEL),
        "artifacts": asdict(ARTIFACTS),
        "seed": seed,
        "train_steps": train_steps,
        "weights": weight_entries,
    }
