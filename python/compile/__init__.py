"""Build-time compile path (L1 Pallas kernels + L2 JAX model + AOT lowering).

Never imported at serving time — rust loads the emitted HLO artifacts via
PJRT. See DESIGN.md §2.
"""
