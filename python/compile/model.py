"""L2 — TinyMM: the multimodal transformer compute graphs.

TinyMM mirrors the LLaVA/Phi3.5-Vision structure at toy scale: a patch
projector maps image-patch features into the token embedding space, vision
and text embeddings are interleaved into one sequence, and a decoder-only
transformer runs over the mix. Three graph variants are lowered by aot.py:

  prefill   — full-sequence forward, emits KV cache + layer-0 DAP stats
  decode    — one-token batched step against a host-owned KV cache
  extend    — S-token chunked step against a host-owned KV cache (the
              batched suffix recompute of partial warm starts: one device
              call processes a whole chunk of text-suffix rows)
  analysis  — prefill variant emitting per-layer observation statistics
              (sparsity rates, DAP column stats, layer-0 probabilities)

The prefill attention and the DAP reduction run through the L1 Pallas
kernels (kernels/attention.py, kernels/dap.py); everything else is plain
jnp. Weight tensors are passed as *inputs* (not baked constants) so the HLO
text stays small and rust can upload them once as device-resident buffers.
"""

import jax
import jax.numpy as jnp

from .config import MODEL, ModelConfig
from .kernels import attention as attn_k
from .kernels import dap as dap_k
from .kernels import ref as kref

# Flat weight order — the contract with rust (manifest.json lists the same
# names in the same order). Per-layer tensors are stacked on a leading
# n_layers axis.
WEIGHT_SPECS = [
    # name, shape-fn(cfg)
    ("embed", lambda c: (c.vocab, c.d_model)),
    ("pos", lambda c: (c.max_pos, c.d_model)),
    ("w_patch", lambda c: (c.patch_dim, c.d_model)),
    ("b_patch", lambda c: (c.d_model,)),
    ("ln1_s", lambda c: (c.n_layers, c.d_model)),
    ("ln1_b", lambda c: (c.n_layers, c.d_model)),
    ("wq", lambda c: (c.n_layers, c.d_model, c.d_attn)),
    ("wk", lambda c: (c.n_layers, c.d_model, c.d_attn)),
    ("wv", lambda c: (c.n_layers, c.d_model, c.d_attn)),
    ("wo", lambda c: (c.n_layers, c.d_attn, c.d_model)),
    ("ln2_s", lambda c: (c.n_layers, c.d_model)),
    ("ln2_b", lambda c: (c.n_layers, c.d_model)),
    ("w1", lambda c: (c.n_layers, c.d_model, c.d_mlp)),
    ("b1", lambda c: (c.n_layers, c.d_mlp)),
    ("w2", lambda c: (c.n_layers, c.d_mlp, c.d_model)),
    ("b2", lambda c: (c.n_layers, c.d_model)),
    ("lnf_s", lambda c: (c.d_model,)),
    ("lnf_b", lambda c: (c.d_model,)),
    ("head", lambda c: (c.d_model, c.vocab)),
]

WEIGHT_NAMES = [n for n, _ in WEIGHT_SPECS]


def weight_shapes(cfg: ModelConfig = MODEL):
    return {name: fn(cfg) for name, fn in WEIGHT_SPECS}


def init_weights(key, cfg: ModelConfig = MODEL):
    """He-style init; returns dict name -> f32 array."""
    shapes = weight_shapes(cfg)
    out = {}
    for name, shape in shapes.items():
        key, sub = jax.random.split(key)
        if name in ("ln1_s", "ln2_s", "lnf_s"):
            out[name] = jnp.ones(shape, jnp.float32)
        elif name in ("ln1_b", "ln2_b", "lnf_b", "b_patch", "b1", "b2"):
            out[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            out[name] = (jax.random.normal(sub, shape, jnp.float32)
                         * (1.0 / jnp.sqrt(jnp.float32(fan_in))))
    return out


def params_tuple(params: dict):
    """Dict -> tuple in WEIGHT_NAMES order (the rust-facing flat order)."""
    return tuple(params[n] for n in WEIGHT_NAMES)


def params_dict(flat):
    return dict(zip(WEIGHT_NAMES, flat))


def _ln(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def embed_sequence(p, ids, patches, is_vision):
    """Mix text-token embeddings and projected patch embeddings.

    ids:       [S] i32 token ids (arbitrary at vision positions)
    patches:   [S, PD] f32 patch features (zero at text positions)
    is_vision: [S] f32
    """
    tok = p["embed"][ids]                                 # [S, D]
    vis = patches @ p["w_patch"] + p["b_patch"]           # [S, D]
    iv = is_vision[:, None]
    return iv * vis + (1.0 - iv) * tok


def _split_heads(x, cfg):
    # [.., D_attn] -> [.., H, Dh]
    return x.reshape(x.shape[:-1] + (cfg.n_heads, cfg.d_head))


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill_fn(cfg: ModelConfig = MODEL, *, use_pallas: bool = True,
               collect_layers: bool = False):
    """Build the prefill graph for a static bucket size.

    Returns fn(*params_flat, ids[S], patches[S,PD], is_vision[S], n_tokens,
               n_prefix)
      -> (logits[V], k[L,S,H,Dh], v[L,S,H,Dh], dap_sum[S], dap_max[S],
          dap_psum[S], dap_pmax[S])
    and, with collect_layers=True, additionally the per-layer stats used by
    the analysis artifact.

    `n_prefix` marks the reusable-prefix boundary (one past the last vision
    token; 0 = none): dap_psum/dap_pmax are the same Eq. 1/3 column
    statistics restricted to text query rows < n_prefix. The rust prefix
    cache stores them with the unpruned prefix KV so a later prompt sharing
    only the prefix can rebuild its OWN full-prompt statistics — cached
    prefix rows + its recomputed suffix rows (decode graph's dap_row) —
    and re-run the pruning decision per request.
    """

    def fn(*args):
        flat, (ids, patches, is_vision, n_tokens, n_prefix) = args[:-5], args[-5:]
        p = params_dict(flat)
        s = ids.shape[0]
        pos_idx = jnp.arange(s)
        valid = (pos_idx < n_tokens).astype(jnp.float32)

        x = embed_sequence(p, ids, patches, is_vision)
        x = x + p["pos"][:s]

        # additive mask: causal AND key-valid (pad keys hidden). Pad *query*
        # rows produce garbage but are never read back.
        causal = jnp.tril(jnp.ones((s, s), jnp.float32))
        vis_mask = causal * valid[None, :]
        mask = jnp.where(vis_mask > 0, 0.0, -1e9).astype(jnp.float32)

        # text-row weight for DAP: valid AND text; the prefix-restricted
        # variant additionally zeroes rows at/after the prefix boundary
        row_w = valid * (1.0 - is_vision)
        row_w_prefix = row_w * (pos_idx < n_prefix).astype(jnp.float32)

        ks, vs = [], []
        dap_sum = dap_max = None
        dap_psum = dap_pmax = None
        layer_stats = []
        for l in range(cfg.n_layers):
            h = _ln(x, p["ln1_s"][l], p["ln1_b"][l])
            q = _split_heads(h @ p["wq"][l], cfg).transpose(1, 0, 2)  # [H,S,Dh]
            k = _split_heads(h @ p["wk"][l], cfg).transpose(1, 0, 2)
            v = _split_heads(h @ p["wv"][l], cfg).transpose(1, 0, 2)
            if use_pallas:
                out, probs = attn_k.attention(q, k, v, mask)
            else:
                out, probs = kref.attention_ref(q, k, v, mask)
            if l == cfg.dap_layer:
                if use_pallas:
                    dap_sum, dap_max = dap_k.dap_stats(probs, row_w)
                    dap_psum, dap_pmax = dap_k.dap_stats(probs, row_w_prefix)
                else:
                    dap_sum, dap_max = kref.dap_stats_ref(probs, row_w)
                    dap_psum, dap_pmax = kref.dap_stats_ref(probs, row_w_prefix)
            if collect_layers:
                # Scale-faithful sparsity threshold: the paper uses
                # ε = 1e-4 at ~2357-token contexts ≈ 0.24× the uniform
                # share 1/n; at TinyMM's context lengths the equivalent
                # relative threshold is ε = 0.25 / n_tokens.
                eps = 0.25 / jnp.maximum(n_tokens.astype(jnp.float32), 1.0)
                sp = kref.sparsity_rates_ref(probs, is_vision, valid, eps)
                cs, cm = kref.dap_stats_ref(probs, row_w)
                layer_stats.append((sp, cs, cm, probs if l == 0 else None))
            out = out.transpose(1, 0, 2).reshape(s, cfg.d_attn)    # [S, D_attn]
            x = x + out @ p["wo"][l]
            h2 = _ln(x, p["ln2_s"][l], p["ln2_b"][l])
            x = x + jax.nn.gelu(h2 @ p["w1"][l] + p["b1"][l]) @ p["w2"][l] + p["b2"][l]
            # store K/V as [S, H, Dh] (slot-major — matches the rust slabs)
            ks.append(k.transpose(1, 0, 2))
            vs.append(v.transpose(1, 0, 2))

        xf = _ln(x, p["lnf_s"], p["lnf_b"])
        last = jnp.clip(n_tokens - 1, 0, s - 1)
        logits = xf[last] @ p["head"]                              # [V]
        k_cache = jnp.stack(ks)                                    # [L,S,H,Dh]
        v_cache = jnp.stack(vs)

        if collect_layers:
            sparsity = jnp.stack([t[0] for t in layer_stats])      # [L,3]
            colsum = jnp.stack([t[1] for t in layer_stats])        # [L,S]
            colmax = jnp.stack([t[2] for t in layer_stats])        # [L,S]
            probs0 = layer_stats[0][3]                             # [H,S,S]
            return (logits, k_cache, v_cache, dap_sum, dap_max,
                    sparsity, colsum, colmax, probs0)
        return (logits, k_cache, v_cache, dap_sum, dap_max,
                dap_psum, dap_pmax)

    return fn


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode_fn(cfg: ModelConfig = MODEL):
    """Build the batched one-token decode graph.

    fn(*params_flat, token[B], pos[B], k_cache[B,L,C,H,Dh],
       v_cache[B,L,C,H,Dh], length[B])
      -> (logits[B,V], k_new[B,L,H,Dh], v_new[B,L,H,Dh],
          attn_mean[B,C], attn_peak[B,C], self_mean[B],
          dap_row[B,C], dap_row_self[B])

    The new token attends to the first length[b] cache slots plus itself;
    its own K/V are returned for rust to append to the host slab. `attn`
    carries the post-softmax probability mass each cache slot received this
    step (per layer and head) — the raw material for H2O/DDES/SnapKV/AdaKV
    accounting; `self_attn` is the mass on the token itself (the initial
    score of the new slot). `dap_row`/`dap_row_self` are the dap layer's
    head-mean probabilities for this query row — exactly one row's
    contribution to the prefill graph's Eq. 1 column sum and Eq. 3 column
    max, which is what lets a partial-prefix warm start rebuild a
    request's own DAP statistics while recomputing only its text suffix
    through this graph.
    """

    def fn(*args):
        flat, (token, pos, k_cache, v_cache, length) = args[:-5], args[-5:]
        p = params_dict(flat)
        b = token.shape[0]
        c = k_cache.shape[2]
        scale = 1.0 / jnp.sqrt(jnp.float32(cfg.d_head))

        x = p["embed"][token] + p["pos"][pos]               # [B, D]
        slot = jnp.arange(c)
        valid = (slot[None, :] < length[:, None]).astype(jnp.float32)  # [B,C]

        k_news, v_news, attns, self_attns = [], [], [], []
        dap_row = dap_row_self = None
        for l in range(cfg.n_layers):
            h = _ln(x, p["ln1_s"][l], p["ln1_b"][l])
            q = _split_heads(h @ p["wq"][l], cfg)            # [B,H,Dh]
            k = _split_heads(h @ p["wk"][l], cfg)
            v = _split_heads(h @ p["wv"][l], cfg)
            kc = k_cache[:, l]                               # [B,C,H,Dh]
            vc = v_cache[:, l]
            scores = jnp.einsum("bhd,bchd->bhc", q, kc) * scale
            scores = jnp.where(valid[:, None, :] > 0, scores, -1e9)
            self_score = jnp.einsum("bhd,bhd->bh", q, k) * scale  # [B,H]
            full = jnp.concatenate([scores, self_score[:, :, None]], axis=-1)
            probs = jax.nn.softmax(full, axis=-1)            # [B,H,C+1]
            pc, ps = probs[:, :, :c], probs[:, :, c]
            if l == cfg.dap_layer:
                # head-mean row of the dap layer: this query's Eq. 1/3
                # contribution per cache column (+ its own column). Must
                # aggregate exactly like kernels/dap.py's pbar (sum over
                # heads / n_heads) so prefill-time and replay-time
                # statistics agree.
                dap_row = jnp.sum(pc, axis=1) / jnp.float32(cfg.n_heads)   # [B,C]
                dap_row_self = jnp.sum(ps, axis=1) / jnp.float32(cfg.n_heads)  # [B]
            out = (jnp.einsum("bhc,bchd->bhd", pc, vc)
                   + ps[:, :, None] * v)                     # [B,H,Dh]
            x = x + out.reshape(b, cfg.d_attn) @ p["wo"][l]
            h2 = _ln(x, p["ln2_s"][l], p["ln2_b"][l])
            x = x + jax.nn.gelu(h2 @ p["w1"][l] + p["b1"][l]) @ p["w2"][l] + p["b2"][l]
            k_news.append(k)
            v_news.append(v)
            attns.append(pc)
            self_attns.append(ps)

        xf = _ln(x, p["lnf_s"], p["lnf_b"])
        logits = xf @ p["head"]                              # [B,V]
        k_new = jnp.stack(k_news, axis=1)                    # [B,L,H,Dh]
        v_new = jnp.stack(v_news, axis=1)
        attn = jnp.stack(attns, axis=1)                      # [B,L,H,C]
        self_attn = jnp.stack(self_attns, axis=1)            # [B,L,H]
        # Reduce the score streams in-graph (§Perf opt 2): the policies
        # consume the layer/head-mean mass per slot plus the max-over-heads
        # (AdaKV's adaptive signal); shipping [B,C]+[B,C]+[B] instead of
        # [B,L,H,C] cuts the per-step device→host transfer ~30×.
        attn_mean = jnp.mean(attn, axis=(1, 2))              # [B,C]
        attn_peak = jnp.max(jnp.mean(attn, axis=1), axis=1)  # [B,C]
        self_mean = jnp.mean(self_attn, axis=(1, 2))         # [B]
        return (logits, k_new, v_new, attn_mean, attn_peak, self_mean,
                dap_row, dap_row_self)

    return fn


# ---------------------------------------------------------------------------
# extend (chunked prefill-with-cache)
# ---------------------------------------------------------------------------

def extend_fn(cfg: ModelConfig = MODEL):
    """Build the chunked extend graph: S new token rows against a cache.

    fn(*params_flat, token[B,S], pos[B,S], k_cache[B,L,C,H,Dh],
       v_cache[B,L,C,H,Dh], length[B], n_new[B])
      -> (logits[B,V], k_new[B,L,S,H,Dh], v_new[B,L,S,H,Dh],
          dap_rows[B,S,C+S])

    The decode graph generalized from one token to a chunk: row i attends
    to the first length[b] cache slots plus chunk rows 0..=i (causal), so
    a partial warm start's text suffix recomputes in ⌈suffix/S⌉ device
    calls instead of one call per token, while every row still sees
    exactly the context it saw in a cold prefill (positions are passed
    explicitly; the cache holds the unpruned prefix). Rows are text-only
    (embed + positional; suffixes never contain vision tokens — see
    prefix::partial_boundary). Rows at and past n_new[b] are padding:
    their outputs are garbage and must not be read; `logits` is taken at
    row n_new[b]-1, the last valid row.

    `dap_rows[b, i]` is the dap layer's head-mean probability row of
    chunk row i — columns 0..C over the cache slots, columns C..C+S over
    the chunk rows (C+i is the row's own column). It aggregates exactly
    like kernels/dap.py's pbar (sum over heads / n_heads) and the decode
    graph's dap_row, so the host can accumulate a chunk row-by-row, in
    row order, and reconstruct bit-for-bit the statistics the one-token
    decode loop would have accumulated.
    """

    def fn(*args):
        flat, (token, pos, k_cache, v_cache, length, n_new) = args[:-6], args[-6:]
        p = params_dict(flat)
        b, s_ = token.shape
        c = k_cache.shape[2]
        scale = 1.0 / jnp.sqrt(jnp.float32(cfg.d_head))

        x = p["embed"][token] + p["pos"][pos]                # [B,S,D]
        slot = jnp.arange(c)
        cache_valid = (slot[None, :] < length[:, None]).astype(jnp.float32)  # [B,C]
        # causal mask among the chunk rows; pad rows (≥ n_new) sit after
        # every valid row, so causality alone already hides them as keys
        causal = jnp.tril(jnp.ones((s_, s_), jnp.float32))   # [S,S]

        k_news, v_news = [], []
        dap_rows = None
        for l in range(cfg.n_layers):
            h = _ln(x, p["ln1_s"][l], p["ln1_b"][l])
            q = _split_heads(h @ p["wq"][l], cfg)            # [B,S,H,Dh]
            k = _split_heads(h @ p["wk"][l], cfg)
            v = _split_heads(h @ p["wv"][l], cfg)
            kc = k_cache[:, l]                               # [B,C,H,Dh]
            vc = v_cache[:, l]
            sc = jnp.einsum("bshd,bchd->bhsc", q, kc) * scale
            sc = jnp.where(cache_valid[:, None, None, :] > 0, sc, -1e9)
            ss = jnp.einsum("bshd,bthd->bhst", q, k) * scale
            ss = jnp.where(causal[None, None, :, :] > 0, ss, -1e9)
            full = jnp.concatenate([sc, ss], axis=-1)        # [B,H,S,C+S]
            probs = jax.nn.softmax(full, axis=-1)
            pc, pi = probs[..., :c], probs[..., c:]
            if l == cfg.dap_layer:
                # head-mean rows — the same reduction as decode's dap_row
                dap_rows = jnp.sum(probs, axis=1) / jnp.float32(cfg.n_heads)
            out = (jnp.einsum("bhsc,bchd->bshd", pc, vc)
                   + jnp.einsum("bhst,bthd->bshd", pi, v))   # [B,S,H,Dh]
            x = x + out.reshape(b, s_, cfg.d_attn) @ p["wo"][l]
            h2 = _ln(x, p["ln2_s"][l], p["ln2_b"][l])
            x = x + jax.nn.gelu(h2 @ p["w1"][l] + p["b1"][l]) @ p["w2"][l] + p["b2"][l]
            k_news.append(k)
            v_news.append(v)

        xf = _ln(x, p["lnf_s"], p["lnf_b"])
        last = jnp.clip(n_new - 1, 0, s_ - 1)                # [B]
        logits = jnp.take_along_axis(xf, last[:, None, None], axis=1)[:, 0] @ p["head"]
        k_new = jnp.stack(k_news, axis=1)                    # [B,L,S,H,Dh]
        v_new = jnp.stack(v_news, axis=1)
        return (logits, k_new, v_new, dap_rows)

    return fn


# ---------------------------------------------------------------------------
# training-time forward (full sequence, logits everywhere) — used by train.py
# ---------------------------------------------------------------------------

def train_forward(params: dict, ids, patches, is_vision, cfg: ModelConfig = MODEL):
    """Batched full-sequence forward returning logits at every position.

    ids:       [N, S] i32
    patches:   [N, S, PD] f32
    is_vision: [N, S] f32
    Returns logits [N, S, V].
    """

    def single(ids1, patches1, isv1):
        s = ids1.shape[0]
        p = params
        x = embed_sequence(p, ids1, patches1, isv1) + p["pos"][:s]
        causal = jnp.tril(jnp.ones((s, s), jnp.float32))
        mask = jnp.where(causal > 0, 0.0, -1e9).astype(jnp.float32)
        for l in range(cfg.n_layers):
            h = _ln(x, p["ln1_s"][l], p["ln1_b"][l])
            q = _split_heads(h @ p["wq"][l], cfg).transpose(1, 0, 2)
            k = _split_heads(h @ p["wk"][l], cfg).transpose(1, 0, 2)
            v = _split_heads(h @ p["wv"][l], cfg).transpose(1, 0, 2)
            out, _ = kref.attention_ref(q, k, v, mask)
            out = out.transpose(1, 0, 2).reshape(s, cfg.d_attn)
            x = x + out @ p["wo"][l]
            h2 = _ln(x, p["ln2_s"][l], p["ln2_b"][l])
            x = x + jax.nn.gelu(h2 @ p["w1"][l] + p["b1"][l]) @ p["w2"][l] + p["b2"][l]
        xf = _ln(x, p["lnf_s"], p["lnf_b"])
        return xf @ p["head"]

    return jax.vmap(single)(ids, patches, is_vision)
