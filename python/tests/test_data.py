"""Synthetic corpus contracts (the layout rust mirrors)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import data as D

settings.register_profile("data", deadline=None, max_examples=20)
settings.load_profile("data")


@given(seed=st.integers(0, 2**31 - 1))
def test_qa_sample_layout(seed):
    rng = np.random.default_rng(seed)
    ids, pat, isv, lw, used = D.qa_sample(rng, 64)
    assert ids[0] == D.BOS
    assert np.all(ids[1:17] == D.IMG)
    assert np.all(isv[1:17] == 1.0)
    assert ids[17] in (D.Q_COLOR, D.Q_SHAPE)
    assert ids[18] == D.ANS_MARK
    answer = ids[19]
    if ids[17] == D.Q_COLOR:
        assert D.COLOR_BASE <= answer < D.COLOR_BASE + D.N_COLORS
    else:
        assert D.SHAPE_BASE <= answer < D.SHAPE_BASE + D.N_SHAPES
    assert ids[20] == D.EOS
    assert used == 21
    # patches zero at text positions
    assert np.all(pat[0] == 0) and np.all(pat[17:] == 0)


@given(seed=st.integers(0, 2**31 - 1))
def test_story_sample_layout(seed):
    rng = np.random.default_rng(seed)
    ids, pat, isv, lw, used = D.story_sample(rng, 96)
    assert ids[0] == D.BOS
    # every image block is followed by STORY_MARK, color, shape
    i = 1
    segments = 0
    while i + D.N_PATCHES + 3 < used and ids[i] == D.IMG:
        assert np.all(ids[i:i + D.N_PATCHES] == D.IMG)
        j = i + D.N_PATCHES
        assert ids[j] == D.STORY_MARK
        assert D.COLOR_BASE <= ids[j + 1] < D.COLOR_BASE + D.N_COLORS
        assert D.SHAPE_BASE <= ids[j + 2] < D.SHAPE_BASE + D.N_SHAPES
        segments += 1
        # skip to the next image block
        i = j + 1
        while i < used and ids[i] != D.IMG and ids[i] != D.EOS:
            i += 1
    assert segments >= 1


def test_story_transition_is_stochastic_and_sparse():
    t = D.story_transition()
    np.testing.assert_allclose(t.sum(1), 1.0, atol=1e-5)
    assert np.all((t > 0).sum(1) <= 6)
    # deterministic across calls
    t2 = D.story_transition()
    assert t is t2 or np.array_equal(t, t2)


def test_informative_patches_carry_signal():
    rng = np.random.default_rng(0)
    patches, mask = D.make_image(rng, 3, 5)
    proto = D.class_prototype(3, 5)
    info = patches[mask]
    back = patches[~mask]
    # informative patches correlate with the prototype, background doesn't
    info_dot = np.abs(info @ proto).mean()
    back_dot = np.abs(back @ proto).mean()
    assert info_dot > 3 * back_dot


def test_batch_shapes():
    rng = np.random.default_rng(1)
    ids, pat, isv, lw = D.batch(rng, 6, 96)
    assert ids.shape == (6, 96)
    assert pat.shape == (6, 96, D.PATCH_DIM)
    assert isv.shape == (6, 96)
    assert lw.shape == (6, 96)
    assert np.all((lw == 0) | (lw == 1))
