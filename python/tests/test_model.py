"""L2 contracts: shapes, prefill/decode consistency, training sanity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import data as D
from compile import model as M
from compile.config import MODEL


@pytest.fixture(scope="module")
def params():
    return M.init_weights(jax.random.PRNGKey(0))


def make_inputs(rng, s, n):
    ids, pat, isv, lw, used = D.qa_sample(rng, s)
    return (jnp.asarray(ids), jnp.asarray(pat), jnp.asarray(isv), jnp.int32(used))


def test_weight_shapes_cover_all_names():
    shapes = M.weight_shapes()
    assert list(shapes.keys()) == M.WEIGHT_NAMES
    assert shapes["embed"] == (MODEL.vocab, MODEL.d_model)
    assert shapes["wq"] == (MODEL.n_layers, MODEL.d_model, MODEL.d_attn)


def test_prefill_output_shapes(params):
    s = 64
    rng = np.random.default_rng(1)
    fn = M.prefill_fn(use_pallas=False)
    ids, pat, isv, n = make_inputs(rng, s, 20)
    logits, k, v, dsum, dmax = fn(*M.params_tuple(params), ids, pat, isv, n)
    assert logits.shape == (MODEL.vocab,)
    assert k.shape == (MODEL.n_layers, s, MODEL.n_heads, MODEL.d_head)
    assert v.shape == k.shape
    assert dsum.shape == (s,)
    assert dmax.shape == (s,)
    assert not np.any(np.isnan(np.asarray(logits)))


def test_prefill_pallas_matches_jnp(params):
    """The pallas-kernel prefill and the pure-jnp prefill agree — the L2
    integration of the L1 kernel is numerically transparent."""
    s = 64
    rng = np.random.default_rng(2)
    args = make_inputs(rng, s, 19)
    out_p = M.prefill_fn(use_pallas=True)(*M.params_tuple(params), *args)
    out_j = M.prefill_fn(use_pallas=False)(*M.params_tuple(params), *args)
    for a, b, name in zip(out_p, out_j, ["logits", "k", "v", "dap_sum", "dap_max"]):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=2e-4, err_msg=name)


def test_decode_consistent_with_prefill(params):
    """Running prefill over [t0..tn] must equal prefill over [t0..tn-1]
    followed by one decode step of tn (same logits)."""
    s = 64
    rng = np.random.default_rng(3)
    ids, pat, isv, lw, used = D.qa_sample(rng, s)
    full = M.prefill_fn(use_pallas=False)(
        *M.params_tuple(params), jnp.asarray(ids), jnp.asarray(pat),
        jnp.asarray(isv), jnp.int32(used))
    logits_full = np.asarray(full[0])

    # prefill without the last token
    part = M.prefill_fn(use_pallas=False)(
        *M.params_tuple(params), jnp.asarray(ids), jnp.asarray(pat),
        jnp.asarray(isv), jnp.int32(used - 1))
    _, k, v, _, _ = part
    # build decode cache [1, L, C, H, Dh] from the first used-1 slots
    c = 128
    kc = np.zeros((1, MODEL.n_layers, c, MODEL.n_heads, MODEL.d_head), np.float32)
    vc = np.zeros_like(kc)
    kc[0, :, : used - 1] = np.asarray(k)[:, : used - 1]
    vc[0, :, : used - 1] = np.asarray(v)[:, : used - 1]

    dec = M.decode_fn()(
        *M.params_tuple(params),
        jnp.asarray([ids[used - 1]], jnp.int32),
        jnp.asarray([used - 1], jnp.int32),
        jnp.asarray(kc),
        jnp.asarray(vc),
        jnp.asarray([used - 1], jnp.int32),
    )
    logits_dec = np.asarray(dec[0])[0]
    np.testing.assert_allclose(logits_dec, logits_full, atol=1e-3)


def test_decode_attention_scores_are_distributions(params):
    rng = np.random.default_rng(4)
    b, c = 2, 128
    kc = rng.standard_normal(
        (b, MODEL.n_layers, c, MODEL.n_heads, MODEL.d_head)).astype(np.float32)
    vc = rng.standard_normal(kc.shape).astype(np.float32)
    lengths = np.asarray([10, 60], np.int32)
    out = M.decode_fn()(
        *M.params_tuple(params),
        jnp.asarray([5, 7], jnp.int32),
        jnp.asarray([10, 60], jnp.int32),
        jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(lengths),
    )
    logits, k_new, v_new, attn_mean, attn_peak, self_mean = out
    attn_mean = np.asarray(attn_mean)
    attn_peak = np.asarray(attn_peak)
    self_mean = np.asarray(self_mean)
    # mean cache mass + mean self mass = 1 per lane (means of distributions)
    total = attn_mean.sum(-1) + self_mean
    np.testing.assert_allclose(total, 1.0, atol=1e-5)
    # peak (max over heads) dominates the head-mean everywhere
    assert np.all(attn_peak >= attn_mean - 1e-7)
    # no attention mass past the live length
    assert np.all(attn_mean[0, 10:] < 1e-9)
    assert np.all(attn_mean[1, 60:] < 1e-9)
    assert k_new.shape == (2, MODEL.n_layers, MODEL.n_heads, MODEL.d_head)


def test_analysis_outputs(params):
    s = 128
    rng = np.random.default_rng(5)
    ids, pat, isv, lw, used = D.story_sample(rng, s)
    out = M.prefill_fn(use_pallas=False, collect_layers=True)(
        *M.params_tuple(params), jnp.asarray(ids), jnp.asarray(pat),
        jnp.asarray(isv), jnp.int32(used))
    assert len(out) == 9
    sparsity = np.asarray(out[5])
    assert sparsity.shape == (MODEL.n_layers, 3)
    assert np.all(sparsity >= 0.0) and np.all(sparsity <= 1.0)
    probs0 = np.asarray(out[8])
    assert probs0.shape == (MODEL.n_heads, s, s)


def test_short_training_reduces_loss():
    from compile import train as T
    params, loss, hist = T.train(steps=8, batch_size=8, seq_len=64,
                                 log_every=4, verbose=False)
    assert hist[0][1] > loss, f"loss should drop: {hist[0][1]} -> {loss}"
    assert np.isfinite(loss)
