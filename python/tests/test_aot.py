"""AOT path: HLO text emission and artifact/manifest integrity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.config import MODEL, ARTIFACTS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_emission_small_graph():
    """Any jitted graph lowers to parseable HLO text (the interchange
    format — serialized protos are rejected by xla_extension 0.5.1)."""
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_weight_specs_contiguous():
    shapes = M.weight_shapes()
    total = sum(int(np.prod(s)) for s in shapes.values())
    assert total == 745_344  # the TinyMM parameter count


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_matches_config():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["model"]["d_model"] == MODEL.d_model
    assert man["model"]["vocab"] == MODEL.vocab
    assert man["model"]["dap_layer"] == MODEL.dap_layer
    assert man["artifacts"]["prefill_buckets"] == ARTIFACTS.prefill_buckets
    names = [w["name"] for w in man["weights"]]
    assert names == M.WEIGHT_NAMES
    # offsets contiguous
    off = 0
    for w in man["weights"]:
        assert w["offset"] == off
        off += w["numel"] * 4


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_artifact_files_exist_and_parse():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for entry in man["artifact_table"]:
        path = os.path.join(ART, entry["name"] + ".hlo.txt")
        assert os.path.exists(path), path
        head = open(path).read(200)
        assert head.startswith("HloModule"), path
    # weights.bin sized per manifest
    total = sum(w["numel"] for w in man["weights"])
    assert os.path.getsize(os.path.join(ART, "weights.bin")) == total * 4
    # grammar exported
    g = np.fromfile(os.path.join(ART, "grammar.bin"), np.float32)
    from compile import data as D
    assert g.size == D.N_STORY_WORDS ** 2
    np.testing.assert_allclose(
        g.reshape(D.N_STORY_WORDS, -1).sum(1), 1.0, atol=1e-4)


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "weights.npz")),
                    reason="artifacts not built")
def test_cached_weights_answer_qa():
    """The shipped weights must actually solve the synthetic QA task."""
    from compile import train as T
    z = np.load(os.path.join(ART, "weights.npz"))
    params = {n: jnp.asarray(z[n]) for n in M.WEIGHT_NAMES}
    acc = T.qa_accuracy(params, n=32)
    assert acc >= 0.9, f"QA accuracy {acc}"
