"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes; assert_allclose against ref.py is THE kernel
correctness signal (interpret=True execution, same lowering the AOT
artifacts embed).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_k
from compile.kernels import dap as dap_k
from compile.kernels import ref as kref

settings.register_profile("kernels", deadline=None, max_examples=12)
settings.load_profile("kernels")


def causal_mask(s, valid_n=None):
    m = np.tril(np.ones((s, s), np.float32))
    if valid_n is not None:
        m[:, valid_n:] = 0.0
    return jnp.asarray(np.where(m > 0, 0.0, -1e9).astype(np.float32))


@given(
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([8, 32, 64, 128]),
    dh=st.sampled_from([4, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(h, s, dh, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((h, s, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((h, s, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((h, s, dh)), jnp.float32)
    mask = causal_mask(s)
    o1, p1 = attn_k.attention(q, k, v, mask)
    o2, p2 = kref.attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-6)


@given(
    s=st.sampled_from([16, 64, 128]),
    valid_frac=st.floats(0.2, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_with_padding_mask(s, valid_frac, seed):
    rng = np.random.default_rng(seed)
    h, dh = 2, 8
    n_valid = max(1, int(s * valid_frac))
    q = jnp.asarray(rng.standard_normal((h, s, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((h, s, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((h, s, dh)), jnp.float32)
    mask = causal_mask(s, n_valid)
    o1, p1 = attn_k.attention(q, k, v, mask)
    # pad keys receive zero probability at valid query rows
    p = np.asarray(p1)
    assert np.all(p[:, :n_valid, n_valid:] < 1e-12)
    # valid rows are proper distributions
    np.testing.assert_allclose(p[:, :n_valid].sum(-1), 1.0, atol=1e-5)
    o2, _ = kref.attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


@given(
    h=st.sampled_from([1, 4]),
    s=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dap_stats_matches_ref(h, s, seed):
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal((h, s, s)).astype(np.float32)
    probs = jnp.asarray(jax.nn.softmax(jnp.asarray(logits), axis=-1))
    w = jnp.asarray((rng.random(s) > 0.4).astype(np.float32))
    s1, m1 = dap_k.dap_stats(probs, w)
    s2, m2 = kref.dap_stats_ref(probs, w)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-6)


def test_dap_stats_zero_weight_rows():
    """All-zero text weights → zero column stats (no NaNs)."""
    h, s = 2, 32
    probs = jnp.full((h, s, s), 1.0 / s, jnp.float32)
    w = jnp.zeros(s, jnp.float32)
    cs, cm = dap_k.dap_stats(probs, w)
    assert np.allclose(np.asarray(cs), 0.0)
    assert np.allclose(np.asarray(cm), 0.0)


@given(
    b=st.sampled_from([1, 2, 4]),
    c=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, c, seed):
    rng = np.random.default_rng(seed)
    h, dh = 4, 16
    q = jnp.asarray(rng.standard_normal((b, h, dh)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, c, h, dh)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, c, h, dh)), jnp.float32)
    lengths = rng.integers(1, c + 1, size=b)
    valid = jnp.asarray(
        (np.arange(c)[None, :] < lengths[:, None]).astype(np.float32))
    o1, p1 = attn_k.decode_attention(q, kc, vc, valid)
    o2, p2 = kref.decode_attention_ref(q, kc, vc, valid)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-6)


def test_attention_probs_are_causal_distributions():
    rng = np.random.default_rng(0)
    h, s, dh = 2, 64, 8
    q = jnp.asarray(rng.standard_normal((h, s, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((h, s, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((h, s, dh)), jnp.float32)
    _, p = attn_k.attention(q, k, v, causal_mask(s))
    p = np.asarray(p)
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-5)
    for i in range(s):
        assert np.all(p[:, i, i + 1:] < 1e-12), f"row {i} leaks future keys"


@pytest.mark.parametrize("block_q", [16, 32, 64])
def test_attention_block_size_invariance(block_q):
    """The BlockSpec tile height must not change the numerics."""
    rng = np.random.default_rng(7)
    h, s, dh = 2, 64, 8
    q = jnp.asarray(rng.standard_normal((h, s, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((h, s, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((h, s, dh)), jnp.float32)
    mask = causal_mask(s)
    o_ref, _ = kref.attention_ref(q, k, v, mask)
    o, _ = attn_k.attention(q, k, v, mask, block_q=block_q)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-5)
